"""Render EXPERIMENTS.md tables from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from ..configs.base import SHAPES
from ..configs.registry import ARCHS
from .dryrun import RESULTS_DIR
from .roofline import HBM_BW, ICI_BW_PER_LINK, ICI_LINKS, PEAK_FLOPS

V5E_HBM_GB = 16.0

_IMPROVEMENT_NOTE = {
    ("compute", "train"): "raise MFU: larger per-device batch or reduce remat recompute",
    ("compute", "prefill"): "fuse attention (flash) to cut non-matmul overhead",
    ("compute", "decode"): "decode is tiny-compute; batch more requests per step",
    ("memory", "train"): "cut HBM traffic: fuse norms/rope into matmuls, microbatch to keep working set in VMEM",
    ("memory", "prefill"): "KV/activation layout: keep heads-last tiles resident, fuse softmax chain",
    ("memory", "decode"): "decode is weight/cache-bandwidth-bound: quantize cache (int8) or shard cache further",
    ("collective", "train"): "re-shard to cut resharding collectives; overlap grad all-reduce with backward",
    ("collective", "prefill"): "avoid logits all-gather: keep vocab-sharded softmax local",
    ("collective", "decode"): "replicate small activations instead of gathering; halo-exchange for weak-memory ops",
}


def _load(mesh_tag: str) -> Dict[str, dict]:
    out = {}
    for arch in ARCHS:
        for s in SHAPES:
            tag = f"{arch}__{s.name}__{mesh_tag}"
            path = os.path.join(RESULTS_DIR, tag + ".json")
            if os.path.exists(path):
                out[(arch, s.name)] = json.load(open(path))
    return out


def fmt_t(x: float) -> str:
    return f"{x:.3g}"


def dryrun_table(mesh_tag: str) -> List[str]:
    data = _load(mesh_tag)
    lines = [
        "| arch | shape | status | sp | arg GB/dev | temp GB/dev | peak GB/dev | fits v5e? | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(data.items()):
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | skipped — {r['reason'].split(' (')[0]} | | | | | | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | ERROR | | | | | | |")
            continue
        mem = r["roofline"]["memory_per_device"]
        arg = mem.get("argument_bytes", 0) / 1e9
        temp = mem.get("temp_bytes", 0) / 1e9
        peak = mem.get("peak_bytes", 0) / 1e9
        fits = "YES" if peak <= V5E_HBM_GB else f"no ({peak/V5E_HBM_GB:.0f}×)"
        lines.append(
            f"| {arch} | {shape} | ok | {'SP' if r.get('sp_mode') else 'DP'} "
            f"| {arg:.1f} | {temp:.1f} | {peak:.1f} | {fits} "
            f"| {r['seconds']['compile']:.0f} |"
        )
    return lines


def roofline_table() -> List[str]:
    data = _load("pod16x16")
    lines = [
        "| arch | shape | T_comp s | T_mem s | T_coll s | bottleneck | MODEL_FLOPS/dev | useful ratio | roofline frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(data.items()):
        if r["status"] != "ok":
            continue
        rc = r.get("roofline_calibrated") or {}
        if "error" in rc or not rc:
            rc = r["roofline"]
        kind = next(s.kind for s in SHAPES if s.name == shape)
        dom = rc["bottleneck"]
        t_dom = max(rc["t_compute"], rc["t_memory"], rc["t_collective"])
        frac = rc["t_compute"] / t_dom if t_dom else 0.0
        note = _IMPROVEMENT_NOTE.get((dom, kind), "")
        lines.append(
            f"| {arch} | {shape} | {fmt_t(rc['t_compute'])} | {fmt_t(rc['t_memory'])} "
            f"| {fmt_t(rc['t_collective'])} | **{dom}** | {rc['model_flops']:.3g} "
            f"| {rc['useful_flops_ratio']:.2f} | {frac:.2f} | {note} |"
        )
    return lines


def collective_table(mesh_tag: str) -> List[str]:
    data = _load(mesh_tag)
    lines = [
        "| arch | shape | all-gather | all-reduce | reduce-scatter | all-to-all | permute | wire GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(data.items()):
        if r["status"] != "ok":
            continue
        rc = r.get("roofline_calibrated") or {}
        src = rc if rc and "error" not in rc else r["roofline"]
        c = src["collective_counts"]
        lines.append(
            f"| {arch} | {shape} | {c.get('all-gather',0):.0f} | {c.get('all-reduce',0):.0f} "
            f"| {c.get('reduce-scatter',0):.0f} | {c.get('all-to-all',0):.0f} "
            f"| {c.get('collective-permute',0):.0f} | {src['wire_bytes']/1e9:.2f} |"
        )
    return lines


def main():
    print("## §Dry-run — single pod 16×16 (256 chips)\n")
    print("\n".join(dryrun_table("pod16x16")))
    print("\n## §Dry-run — multi-pod 2×16×16 (512 chips)\n")
    print("\n".join(dryrun_table("pod2x16x16")))
    print("\n## §Roofline — single pod, calibrated (trip-count-corrected)\n")
    print("\n".join(roofline_table()))
    print("\n## Collective schedule (single pod, calibrated counts)\n")
    print("\n".join(collective_table("pod16x16")))


if __name__ == "__main__":
    main()
