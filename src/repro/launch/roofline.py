"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh) cell, from the post-SPMD compiled
module (all quantities PER DEVICE — verified against MODEL_FLOPS in tests):

  T_compute    = flops / PEAK_FLOPS
  T_memory     = hbm_bytes / HBM_BW
  T_collective = Σ collective wire bytes / (ICI_LINKS · ICI_BW)

cost_analysis() supplies flops and bytes; collective bytes are NOT in
cost_analysis, so we parse the optimized HLO text: every line defines
``%name = type[shape] op(operands…)`` — we keep a name→bytes table and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, with op-specific wire multipliers (ring all-reduce
moves ≈2× its payload, all-gather/reduce-scatter ≈1× the large side,
permute exactly 1×).

Hardware constants (TPU v5e, per the brief): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI, 4 links usable per chip on a 2-D torus-like mesh
(2 per torus dimension) — the per-chip collective bandwidth denominator.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s
ICI_LINKS = 4  # usable links/chip for collectives on a 2D mesh

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^)]*\)|[\w()]+\[[\d,]*\])"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# wire multiplier per payload byte (ring algorithms, large-n asymptotics)
_WIRE_FACTOR = {
    "all-gather": 1.0,  # payload counted as the gathered (output) size
    "all-reduce": 2.0,  # reduce-scatter + all-gather
    "reduce-scatter": 1.0,  # payload = input size
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    payload_bytes: Dict[str, float]
    wire_bytes: float

    @property
    def total_payload(self) -> float:
        return sum(self.payload_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective payload/wire bytes from optimized HLO text.

    Payload convention: the LARGER of (operand sum, output) — the
    full-tensor side of gather/scatter ops — then op-specific wire factors.
    Ops inside while/fusion bodies appear once; scan-looped collectives are
    multiplied by the trip count when annotatable (XLA does not expose trip
    counts in text reliably — we conservatively count once and report the
    loop-adjusted number separately in the dry-run JSON via scan metadata).
    """
    name_bytes: Dict[str, int] = {}
    counts = {c: 0 for c in _COLLECTIVES}
    payload = {c: 0.0 for c in _COLLECTIVES}

    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str = m.group(1), m.group(2)
        out_bytes = _shape_bytes(type_str)
        name_bytes[name.lstrip("%")] = out_bytes
        op_m = re.search(r"=\s*[^=]*?\b([a-z0-9\-]+)\(", line)
        if not op_m:
            continue
        op = op_m.group(1)
        if op not in _COLLECTIVES:
            continue
        counts[op] += 1
        # operand sizes from the name table
        operand_names = re.findall(r"%?([\w.\-]+)(?:\.clone)?(?=[,)])", line.split("(", 1)[1] if "(" in line else "")
        in_bytes = sum(name_bytes.get(n, 0) for n in operand_names)
        payload[op] += float(max(in_bytes, out_bytes))

    wire = sum(payload[c] * _WIRE_FACTOR[c] for c in _COLLECTIVES)
    return CollectiveStats(counts=counts, payload_bytes=payload, wire_bytes=wire)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_flops_ratio: float
    collective_counts: Dict[str, int]
    memory_per_device: Dict[str, float]

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (train) or 2·N_active·D (fwd-only)."""
    n_active = active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def active_param_count(cfg) -> float:
    """Active (per-token) parameter count — MoE counts top_k+shared experts."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.resolved_head_dim
    if cfg.attn == "mla":
        m = cfg.mla
        qd = cfg.n_heads * (m.nope_head_dim + m.rope_head_dim)
        attn = d * (m.q_lora_rank or 0) + (m.q_lora_rank or d) * qd
        if not m.q_lora_rank:
            attn = d * qd
        attn += d * m.kv_lora_rank + m.kv_lora_rank * cfg.n_heads * (
            m.nope_head_dim + m.v_head_dim
        )
        attn += d * m.rope_head_dim + cfg.n_heads * m.v_head_dim * d
    else:
        attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.moe is not None:
        ffn = 3 * d * cfg.moe.d_ff_expert * (cfg.moe.top_k + cfg.moe.num_shared)
    elif cfg.d_ff:
        ffn = 3 * d * cfg.d_ff
    else:
        ffn = 0
    if cfg.family == "ssm":
        d_in = 2 * d
        mix = d * 2 * d_in + d_in * 3 * d_in + d_in * d  # mLSTM-ish per block
        attn, ffn = 0, mix
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * d
        mamba = d * (2 * d_in + 2 * s.state_dim + d_in // s.head_dim) + d_in * d
        shared = (attn + 3 * d * cfg.d_ff) / max(cfg.shared_attn_every, 1)
        attn, ffn = shared, mamba
    enc = cfg.enc_layers * (attn + ffn) if cfg.family == "encdec" else 0
    return L * (attn + ffn) + enc + 2 * V * d


def compute_roofline(
    compiled,
    cfg,
    shape,
    mesh_devices: int,
    *,
    hlo_text: Optional[str] = None,
) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": float(
                getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                + getattr(ma, "temp_size_in_bytes", 0)
            ),
        }
    except Exception:
        pass

    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_n = coll.wire_bytes / (ICI_LINKS * ICI_BW_PER_LINK)
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_estimate(cfg, shape) / mesh_devices  # per-device share
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        wire_bytes=coll.wire_bytes,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_n,
        bottleneck=bottleneck,
        model_flops=mf,
        useful_flops_ratio=(mf / flops) if flops else 0.0,
        collective_counts=coll.counts,
        memory_per_device=mem,
    )
