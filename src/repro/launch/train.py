"""Training driver: real steps on whatever devices exist.

On the production pod this is launched once per host (jax.distributed);
here it runs CPU-scale configs end-to-end with the full substrate: sharded
params, fault-tolerant loop (async checkpoints, resume, straggler monitor),
deterministic data pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3 --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.registry import get_arch
from ..data.tokens import SyntheticTokenPipeline
from ..models import init_params
from ..models.layers import DTYPE
from ..parallel import sharding as shr
from ..runtime.fault import FaultTolerantLoop
from ..training.optimizer import adamw_init, cosine_schedule
from ..training.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--f32", action="store_true", help="f32 params (CPU default)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.float32 if args.f32 else DTYPE

    devices = jax.devices()
    mesh = jax.make_mesh(
        (len(devices), 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )

    pipe = SyntheticTokenPipeline(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
    )
    key = jax.random.PRNGKey(0)
    with mesh, jax.sharding.set_mesh(mesh):
        params = init_params(key, cfg, dtype=dtype)
        opt = adamw_init(params)
        pspecs = shr.param_pspecs(params, mesh)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs
        )
        step_fn = jax.jit(
            make_train_step(
                cfg,
                lr_fn=cosine_schedule(args.lr, warmup=10, total=args.steps),
                accum=args.accum,
            )
        )

        loop = FaultTolerantLoop(args.ckpt_dir, every=args.ckpt_every)
        (params, opt), start = loop.restore_or((params, opt))
        if start:
            print(f"[train] resumed from step {start}")

        batch_sharding = NamedSharding(mesh, P("data", None))
        t0 = time.time()
        for step in range(start, args.steps):
            hb = pipe.host_batch(step)
            batch = {
                k: jax.device_put(v, batch_sharding) for k, v in hb.items()
            }
            params, opt, metrics = step_fn(params, opt, batch)
            loop.after_step(step, (params, opt))
            if step % 10 == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                print(
                    f"[train] step {step:4d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                    f"lr={m['lr']:.2e} ({time.time()-t0:.1f}s)"
                )
        loop.checkpoint_now()
        loop.close()
        print(f"[train] done: {args.steps} steps in {time.time()-t0:.1f}s; "
              f"checkpoints in {args.ckpt_dir}")
        return float(metrics["loss"])


if __name__ == "__main__":
    main()
