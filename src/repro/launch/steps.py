"""Per-cell step builders: (arch × shape × mesh) → pjit-ready functions with
full input/output sharding trees + ShapeDtypeStruct inputs.

This is the single place where logical sharding policy is decided per cell:
  * train/prefill/decode with global_batch ≥ data-axis size → batch DP;
  * long-context cells (global_batch < data size) → SP mode: the sequence
    (and cache sequence) axis takes the data axis instead;
  * the pod axis is always an outer DP axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig, SHAPES_BY_NAME, cell_is_runnable
from ..configs.registry import get_arch
from ..models import decode_step, forward, init_params, input_specs, prefill
from ..models.layers import DTYPE
from ..parallel import sharding as shr
from ..training.optimizer import adamw_init
from ..training.train_step import make_train_step

_CACHE_RULES: Dict[str, Tuple[Any, ...]] = {
    # leaf name → logical axes, EXCLUDING the leading stacked-layer axis
    "k": ("batch", "seq", "kv", None),
    "v": ("batch", "seq", "kv", None),
    "lat": ("batch", "seq", None),
    "pos": (None,),
    "ssd": ("batch", "heads", None, None),
    "conv": ("batch", None, "ff"),
    "C": ("batch", "heads", None, None),
    "n": ("batch", "heads", None),
    "m": ("batch", "heads"),
    "h": ("batch", "heads", None),
    "c": ("batch", "heads", None),
}


def _cache_pspecs(cache_tree: Any, mesh) -> Any:
    def rule(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", None)
            if isinstance(key, str):
                name = key
                break
        axes = _CACHE_RULES.get(name or "", None)
        if axes is None:
            axes = (None,) * leaf.ndim
        elif len(axes) + 1 == leaf.ndim:
            axes = (None,) + tuple(axes)  # stacked layer/app axis
        elif len(axes) != leaf.ndim:
            axes = (None,) * leaf.ndim
        return shr.logical_to_spec(axes, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def _batch_pspecs(specs: Dict[str, Any], mesh, kind: str) -> Dict[str, Any]:
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = _cache_pspecs(v, mesh)
        elif k == "pos":
            out[k] = P()
        elif k == "tokens" and v.ndim == 1:  # decode tokens (B,)
            out[k] = shr.logical_to_spec(("batch",), v.shape, mesh)
        elif k in ("tokens", "labels"):
            out[k] = shr.logical_to_spec(("batch", "seq"), v.shape, mesh)
        elif k in ("frames", "patch_embeds"):
            out[k] = shr.logical_to_spec(("batch", "seq", None), v.shape, mesh)
        else:
            out[k] = P()
    return out


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch × shape × mesh) cell."""

    cfg: ArchConfig
    shape: ShapeConfig
    mesh: Any
    fn: Callable  # the step function
    args_sds: Tuple[Any, ...]  # ShapeDtypeStructs for .lower(*args)
    in_specs: Tuple[Any, ...]
    out_specs: Any
    sp_mode: bool

    def jitted(self):
        ns = lambda tree: jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tree
        )
        kw = {}
        if self.shape.kind == "decode":
            # §Perf C1: donate the cache — the in-place dynamic_update_slice
            # aliases instead of copying the whole cache every step.
            kw["donate_argnums"] = (1,)
        elif self.shape.kind == "train":
            kw["donate_argnums"] = (0, 1)  # params + optimizer state
        return jax.jit(
            self.fn,
            in_shardings=ns(self.in_specs),
            out_shardings=ns(self.out_specs),
            **kw,
        )

    def lower(self):
        with self.mesh, jax.sharding.set_mesh(self.mesh):
            shr.set_sp_mode(self.sp_mode)
            try:
                return self.jitted().lower(*self.args_sds)
            finally:
                shr.set_sp_mode(False)


def _use_sp(shape: ShapeConfig, mesh) -> bool:
    data = shr.mesh_axis_size(mesh, ("pod", "data"))
    return shape.global_batch % data != 0 or shape.global_batch < data


def build_cell(
    arch: str | ArchConfig,
    shape: str | ShapeConfig,
    mesh,
    *,
    dtype=DTYPE,
    accum: int = 1,
    fused_loss: bool = False,
) -> Cell:
    cfg = get_arch(arch) if isinstance(arch, str) else arch
    shape = SHAPES_BY_NAME[shape] if isinstance(shape, str) else shape
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        raise ValueError(f"cell ({cfg.name} × {shape.name}) skipped: {why}")

    sp = _use_sp(shape, mesh)
    shr.set_sp_mode(sp)
    try:
        key = jax.random.PRNGKey(0)
        params_sds = jax.eval_shape(
            functools.partial(init_params, cfg=cfg, dtype=dtype), key
        )
        pspecs = shr.param_pspecs(params_sds, mesh)
        batch_sds = input_specs(cfg, shape)
        bspecs = _batch_pspecs(batch_sds, mesh, shape.kind)

        if shape.kind == "train":
            step = make_train_step(cfg, accum=accum, fused_loss=fused_loss)
            opt_sds = jax.eval_shape(adamw_init, params_sds)
            # ZeRO-1: moments additionally sharded over the data(+pod) axes
            ospecs = shr.zero1_pspecs(opt_sds.m, mesh)
            opt_specs = type(opt_sds)(m=ospecs, v=ospecs, step=P())
            metric_specs = {
                k: P() for k in ("ce", "lb_loss", "z_loss", "loss", "lr")
            }
            return Cell(
                cfg=cfg, shape=shape, mesh=mesh, fn=step,
                args_sds=(params_sds, opt_sds, batch_sds),
                in_specs=(pspecs, opt_specs, bspecs),
                out_specs=(pspecs, opt_specs, metric_specs),
                sp_mode=sp,
            )

        if shape.kind == "prefill":
            def prefill_step(params, batch):
                return prefill(params, batch, cfg)

            logits_cache_sds = jax.eval_shape(prefill_step, params_sds, batch_sds)
            logits_spec = shr.logical_to_spec(
                ("batch", "vocab"), logits_cache_sds[0].shape, mesh
            )
            cache_specs = _cache_pspecs(logits_cache_sds[1], mesh)
            return Cell(
                cfg=cfg, shape=shape, mesh=mesh, fn=prefill_step,
                args_sds=(params_sds, batch_sds),
                in_specs=(pspecs, bspecs),
                out_specs=(logits_spec, cache_specs),
                sp_mode=sp,
            )

        # decode
        def decode_fn(params, cache, batch):
            return decode_step(params, cache, batch, cfg)

        cache_sds = batch_sds.pop("cache")
        cache_specs = _cache_pspecs(cache_sds, mesh)
        bspecs.pop("cache", None)
        out_sds = jax.eval_shape(decode_fn, params_sds, cache_sds, batch_sds)
        logits_spec = shr.logical_to_spec(("batch", "vocab"), out_sds[0].shape, mesh)
        return Cell(
            cfg=cfg, shape=shape, mesh=mesh, fn=decode_fn,
            args_sds=(params_sds, cache_sds, batch_sds),
            in_specs=(pspecs, cache_specs, bspecs),
            out_specs=(logits_spec, cache_specs),
            sp_mode=sp,
        )
    finally:
        shr.set_sp_mode(False)
