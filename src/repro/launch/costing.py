"""Calibrated FLOP/byte/collective costing for scanned models.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified: lowering the same model at L=2/4/8 layers returns the
same flops).  All models here scan their layer stack, so raw cost_analysis
massively undercounts.

Calibration: lower the SAME cell with the layer stack python-UNROLLED at
two reduced depths L1 < L2 (full batch/seq/vocab — only depth changes) and
extrapolate linearly:

    per_layer = (f(L2) − f(L1)) / (L2 − L1)
    total(L)  = f(L1) + per_layer · (L − L1)

Exact for homogeneous stacks (all assigned archs are, by construction;
zamba2's period-6 shared-attention pattern calibrates at L1, L2 multiples
of 6; whisper scales enc and dec depth together).  Collective wire bytes
and counts are calibrated the same way from the unrolled HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax

from ..configs.base import ArchConfig, ShapeConfig
from .roofline import parse_collectives
from .steps import build_cell


def _calib_depths(cfg: ArchConfig) -> Tuple[int, int]:
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        return k, 2 * k  # one vs two shared-attn applications
    if cfg.family == "ssm" and cfg.slstm_every:
        return 2, 4  # one vs two (mLSTM, sLSTM) pairs
    return 2, 4


def _reduced(cfg: ArchConfig, L: int) -> ArchConfig:
    r = dataclasses.replace(cfg, n_layers=L, unroll_layers=True)
    if cfg.family == "encdec":
        r = dataclasses.replace(r, enc_layers=L)
    return r


def _depth_units(cfg: ArchConfig) -> int:
    """How many calibration units the full config has (== n_layers; whisper's
    enc depth co-scales so n_layers is still the unit count)."""
    return cfg.n_layers


@dataclasses.dataclass
class CalibratedCost:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    collective_counts: Dict[str, float]
    raw: Dict[str, Any]

    def to_dict(self):
        return dataclasses.asdict(self)


def _measure(cfg, shape, mesh, **kw) -> Tuple[float, float, float, Dict[str, int]]:
    cell = build_cell(cfg, shape, mesh, **kw)
    compiled = cell.lower().compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = parse_collectives(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        coll.wire_bytes,
        coll.counts,
    )


def calibrated_cost(cfg: ArchConfig, shape: ShapeConfig, mesh, **kw) -> CalibratedCost:
    l1, l2 = _calib_depths(cfg)
    f1 = _measure(_reduced(cfg, l1), shape, mesh, **kw)
    f2 = _measure(_reduced(cfg, l2), shape, mesh, **kw)
    L = _depth_units(cfg)

    def extrap(a, b):
        per = (b - a) / (l2 - l1)
        return a + per * (L - l1)

    flops = extrap(f1[0], f2[0])
    hbm = extrap(f1[1], f2[1])
    wire = extrap(f1[2], f2[2])
    counts = {
        k: extrap(float(f1[3][k]), float(f2[3][k])) for k in f1[3]
    }
    return CalibratedCost(
        flops=flops,
        hbm_bytes=hbm,
        wire_bytes=wire,
        collective_counts=counts,
        raw={
            "depths": [l1, l2],
            "flops": [f1[0], f2[0]],
            "hbm": [f1[1], f2[1]],
            "wire": [f1[2], f2[2]],
        },
    )
