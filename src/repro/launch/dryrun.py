import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run (brief deliverable e).

For every (architecture × input shape) cell, on the 16×16 single-pod mesh
and the 2×16×16 multi-pod mesh:  jit(step, in_shardings, out_shardings)
.lower(**input_specs).compile() must SUCCEED; we record memory_analysis()
(fits-in-HBM proof), cost_analysis() (FLOPs/bytes for §Roofline) and the
collective schedule parsed from the optimized HLO.

Results are cached as JSON under results/dryrun/ so EXPERIMENTS.md tables
regenerate without recompiling.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3 --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-smallest]
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from ..configs.base import SHAPES, SHAPES_BY_NAME, cell_is_runnable  # noqa: E402
from ..configs.registry import ARCHS, get_arch  # noqa: E402
from .costing import calibrated_cost  # noqa: E402
from .mesh import make_production_mesh, mesh_device_count  # noqa: E402
from .roofline import (  # noqa: E402
    HBM_BW,
    ICI_BW_PER_LINK,
    ICI_LINKS,
    PEAK_FLOPS,
    compute_roofline,
    model_flops_estimate,
)
from .steps import build_cell  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _calibrated_roofline(cfg, shape, mesh, n_devices, **kw):
    """Trip-count-corrected roofline terms (see costing.py)."""
    cal = calibrated_cost(cfg, shape, mesh, **kw)
    t_c = cal.flops / PEAK_FLOPS
    t_m = cal.hbm_bytes / HBM_BW
    t_n = cal.wire_bytes / (ICI_LINKS * ICI_BW_PER_LINK)
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    mf = model_flops_estimate(cfg, shape) / n_devices
    return {
        "flops": cal.flops,
        "hbm_bytes": cal.hbm_bytes,
        "wire_bytes": cal.wire_bytes,
        "t_compute": t_c,
        "t_memory": t_m,
        "t_collective": t_n,
        "bottleneck": max(terms, key=terms.get),
        "model_flops": mf,
        "useful_flops_ratio": (mf / cal.flops) if cal.flops else 0.0,
        "collective_counts": cal.collective_counts,
        "calibration_raw": cal.raw,
    }


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, out_dir: str = RESULTS_DIR, fused_loss: bool = False):
    cfg = get_arch(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{cfg.name}__{shape.name}__{mesh_tag}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, tag + ".json")

    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        result = {"cell": tag, "status": "skipped", "reason": why}
        json.dump(result, open(out_path, "w"), indent=1)
        print(f"[dryrun] {tag}: SKIPPED ({why})")
        return result

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(cfg, shape, mesh, fused_loss=fused_loss)
        t_build = time.time() - t0
        lowered = cell.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0
        hlo = compiled.as_text()
        roof = compute_roofline(
            compiled, cfg, shape, mesh_device_count(mesh), hlo_text=hlo
        )
        ma = compiled.memory_analysis()
        print(f"[dryrun] {tag}: memory_analysis:", ma)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        print(
            f"[dryrun] {tag}: cost_analysis flops={cost.get('flops', 0):.3e} "
            f"bytes={cost.get('bytes accessed', 0):.3e}"
        )
        result = {
            "cell": tag,
            "status": "ok",
            "arch": cfg.name,
            "shape": shape.name,
            "mesh": mesh_tag,
            "sp_mode": cell.sp_mode,
            "seconds": {"build": t_build, "lower": t_lower, "compile": t_compile},
            "roofline": roof.to_dict(),
            "hlo_bytes": len(hlo),
        }
        if not multi_pod:  # roofline table is single-pod (brief); calibrate there
            try:
                result["roofline_calibrated"] = _calibrated_roofline(
                    cfg, shape, mesh, mesh_device_count(mesh), fused_loss=fused_loss
                )
            except Exception as e:
                result["roofline_calibrated"] = {
                    "error": f"{type(e).__name__}: {str(e)[:500]}"
                }
        json.dump(result, open(out_path, "w"), indent=1)
        print(
            f"[dryrun] {tag}: OK  bottleneck={roof.bottleneck} "
            f"T=(c {roof.t_compute:.3e}, m {roof.t_memory:.3e}, n {roof.t_collective:.3e})s "
            f"useful={roof.useful_flops_ratio:.2f} compile={t_compile:.0f}s"
        )
        return result
    except Exception as e:
        result = {
            "cell": tag,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        json.dump(result, open(out_path, "w"), indent=1)
        print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {str(e)[:300]}")
        return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or alias (see configs)")
    ap.add_argument("--shape", default=None, choices=[s.name for s in SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--fused-loss", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in sorted(ARCHS):
            for s in SHAPES:
                meshes = (False, True) if args.both_meshes else (args.multi_pod,)
                for mp in meshes:
                    cells.append((a, s.name, mp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    statuses = {}
    for a, s, mp in cells:
        tag = f"{get_arch(a).name}__{s}__{'pod2x16x16' if mp else 'pod16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            prev = json.load(open(path))
            if prev.get("status") in ("ok", "skipped"):
                print(f"[dryrun] {tag}: cached ({prev['status']})")
                statuses[tag] = prev["status"]
                continue
        r = run_cell(a, s, mp, args.out, fused_loss=args.fused_loss)
        statuses[tag] = r["status"]

    n_ok = sum(1 for v in statuses.values() if v == "ok")
    n_skip = sum(1 for v in statuses.values() if v == "skipped")
    n_err = sum(1 for v in statuses.values() if v == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
