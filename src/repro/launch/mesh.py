"""Production mesh construction (brief-mandated shapes).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax init; tests keep their
single CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: ("data", "model") — DP×TP/EP; the multi-pod "pod" axis is an outer
    pure-DP axis (gradient reduction crosses pods once per step).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_test_mesh(data: int = 4, model: int = 2):
    """Small host-device mesh for distributed unit tests."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def mesh_device_count(mesh) -> int:
    n = 1
    for v in dict(mesh.shape).values():
        n *= v
    return n
