"""Serving driver: batched generation against any assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3 --reduced \
      --batch 8 --prompt-len 32 --max-new 32 [--quantize]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs.registry import get_arch
from ..models import init_params
from ..serving.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--quantize", action="store_true", help="int8 weights (§Perf C3)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(args.seed), cfg, dtype=jnp.float32)
    eng = ServeEngine(
        cfg, params,
        max_len=args.prompt_len + args.max_new,
        quantize=args.quantize,
    )
    prompts = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    key = jax.random.PRNGKey(args.seed + 2) if args.temperature > 0 else None

    t0 = time.time()
    out = eng.generate(prompts, args.max_new, temperature=args.temperature, key=key)
    cold = time.time() - t0
    t0 = time.time()
    out = eng.generate(prompts, args.max_new, temperature=args.temperature, key=key)
    warm = time.time() - t0
    tps = args.batch * args.max_new / warm
    print(
        f"[serve] {cfg.name}{' int8' if args.quantize else ''}: "
        f"{args.batch}×{args.max_new} tokens — cold {cold:.2f}s, warm {warm:.2f}s "
        f"({tps:.0f} tok/s); first row: {out.tokens[0][:10].tolist()}"
    )
    return tps


if __name__ == "__main__":
    main()
