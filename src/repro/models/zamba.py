"""Zamba2-style hybrid backbone: Mamba2 trunk + one SHARED attention block
applied every ``shared_attn_every`` layers (arXiv:2411.15242).

81 Mamba2 layers are scanned (stacked params); the shared transformer block
(full attention + SwiGLU MLP, one set of weights) fires at layer indices
i % every == 0 via lax.cond inside the scan — ⌈81/6⌉ = 14 applications,
each with its OWN KV cache slot (weights shared, caches not).

Simplifications vs. the released checkpoints (DESIGN.md §Arch-applicability):
the concat-with-embedding input and per-application LoRA deltas on the
shared block are omitted — the compute/communication structure (the object
of this reproduction) is unchanged.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import gqa_apply, gqa_init, gqa_cache_spec
from .layers import DTYPE, dense_init, embed_init, mlp_init, rms_norm, scan_layers, swiglu
from .ssm import mamba2_apply, mamba2_init, mamba2_state_spec
from ..parallel.sharding import shard

Params = Dict[str, Any]


def _n_apps(cfg) -> int:
    return -(-cfg.n_layers // cfg.shared_attn_every)


def zamba_init(key, cfg, dtype=DTYPE) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 5)
    mamba_layers = [
        {
            "norm": jnp.ones((cfg.d_model,), dtype),
            "mixer": mamba2_init(ks[i], cfg, dtype),
        }
        for i in range(cfg.n_layers)
    ]
    k1, k2 = jax.random.split(ks[-1])
    return {
        "embed": embed_init(ks[-2], cfg.vocab, cfg.d_model, dtype),
        "mamba_layers": jax.tree.map(lambda *x: jnp.stack(x), *mamba_layers),
        "shared_attn": {
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": gqa_init(k1, cfg, dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
        },
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[-3], cfg.d_model, cfg.vocab, dtype),
    }


def _shared_block(p, x, cfg, positions, cache=None, pos=None, return_cache=False):
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    a, new_cache = gqa_apply(
        p["attn"], h, cfg, positions, cache=cache, pos=pos, return_cache=return_cache
    )
    x = x + a
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    return x + swiglu(h, **p["mlp"]), new_cache


def zamba_forward(
    p: Params, tokens: jax.Array, cfg, *, remat: bool = True,
    return_hidden: bool = False,
) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0)
    x = shard(x, ("batch", "seq", None))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    every = cfg.shared_attn_every
    shared = p["shared_attn"]

    def body(x, scanned):
        lp, idx = scanned
        x = jax.lax.cond(
            idx % every == 0,
            lambda x: _shared_block(shared, x, cfg, positions)[0],
            lambda x: x,
            x,
        )
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        m, _ = mamba2_apply(lp["mixer"], h, cfg)
        return x + m, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = scan_layers(body, x, (p["mamba_layers"], jnp.arange(cfg.n_layers)), cfg.unroll_layers)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return shard(jnp.einsum("bsd,dv->bsv", x, p["lm_head"]), ("batch", "seq", "vocab"))


def zamba_prefill(p: Params, tokens: jax.Array, cfg):
    """→ (last logits, {"ssm": (L,…) states, "attn": (A,…) kv caches})."""
    x = jnp.take(p["embed"], tokens, axis=0)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    every = cfg.shared_attn_every
    shared = p["shared_attn"]
    n_apps = _n_apps(cfg)

    # attention cache template for stacking
    def attn_app(x):
        return _shared_block(shared, x, cfg, positions, return_cache=True)

    def body(carry, scanned):
        x, attn_caches = carry
        lp, idx = scanned

        def with_attn(x):
            x2, cache = attn_app(x)
            app = idx // every
            new_caches = jax.tree.map(
                lambda st, c: jax.lax.dynamic_update_slice_in_dim(
                    st, c[None].astype(st.dtype), app, axis=0
                ),
                attn_caches,
                cache,
            )
            return x2, new_caches

        x, attn_caches = jax.lax.cond(
            idx % every == 0, with_attn, lambda x: (x, attn_caches), x
        )
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        m, state = mamba2_apply(lp["mixer"], h, cfg, return_state=True)
        return (x + m, attn_caches), state

    attn_caches0 = jax.tree.map(
        lambda s: jnp.zeros((n_apps,) + s.shape, s.dtype),
        gqa_cache_spec(cfg, tokens.shape[0], tokens.shape[1], dtype=x.dtype),
    )
    (x, attn_caches), ssm_states = scan_layers(
        body, (x, attn_caches0), (p["mamba_layers"], jnp.arange(cfg.n_layers)),
        cfg.unroll_layers,
    )
    x = rms_norm(x[:, -1:], p["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])[:, 0]
    return logits, {"ssm": ssm_states, "attn": attn_caches}


def zamba_decode_step(p: Params, cache, tokens: jax.Array, pos, cfg):
    x = jnp.take(p["embed"], tokens[:, None], axis=0)
    positions = jnp.full((1,), pos, jnp.int32)
    every = cfg.shared_attn_every
    shared = p["shared_attn"]

    def body(carry, scanned):
        x, attn_caches = carry
        lp, ssm_state, idx = scanned

        def with_attn(x):
            app = idx // every
            cache_app = jax.tree.map(lambda st: st[app], attn_caches)
            x2, new_c = _shared_block(shared, x, cfg, positions, cache=cache_app, pos=pos)
            new_caches = jax.tree.map(
                lambda st, c: jax.lax.dynamic_update_slice_in_dim(
                    st, c[None].astype(st.dtype), app, axis=0
                ),
                attn_caches,
                new_c,
            )
            return x2, new_caches

        x, attn_caches = jax.lax.cond(
            idx % every == 0, with_attn, lambda x: (x, attn_caches), x
        )
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        m, new_state = mamba2_apply(lp["mixer"], h, cfg, state=ssm_state)
        return (x + m, attn_caches), new_state

    (x, attn_caches), new_ssm = scan_layers(
        body, (x, cache["attn"]),
        (p["mamba_layers"], cache["ssm"], jnp.arange(cfg.n_layers)),
        cfg.unroll_layers,
    )
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])[:, 0]
    return logits, {"ssm": new_ssm, "attn": attn_caches}


def zamba_cache_spec(cfg, batch: int, seq_len: int, dtype=DTYPE):
    n_apps = _n_apps(cfg)
    ssm = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
        mamba2_state_spec(cfg, batch, dtype),
    )
    attn = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_apps,) + s.shape, s.dtype),
        gqa_cache_spec(cfg, batch, seq_len, dtype),
    )
    return {"ssm": ssm, "attn": attn}
