"""Shared model primitives (pure JAX, pytree params, no framework deps).

Parameters are nested dicts of jnp arrays.  Every initializer has a
matching logical-sharding spec in `repro.parallel.sharding` (specs are
derived from array *names*, mirrored by structure).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

DTYPE = jnp.bfloat16  # activation / param dtype for the large configs


def dense_init(key, in_dim: int, out_dim: int, dtype=DTYPE) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=DTYPE) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm (f32 internal compute).

    NOTE (§Perf B3, refuted): a custom-vjp variant casting cotangents to
    x.dtype was tried to halve the f32 TP all-reduce wire bytes; measured
    wire went UP 12% (the custom vjp pins residuals and blocks XLA fusions
    that the plain form enjoys) — reverted.  See EXPERIMENTS.md §Perf.
    """
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float, *, head_axis: bool | None = None
) -> jax.Array:
    """Rotary embedding.

    x: (..., S, H, D) when ``head_axis`` (default for 4-D+), else (..., S, D).
    positions: (S,) absolute positions.
    """
    d = x.shape[-1]
    if head_axis is None:
        head_axis = x.ndim >= 4
    inv = rope_frequencies(d, theta)  # (d/2,)
    ang = positions[:, None].astype(jnp.float32) * inv  # (S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if head_axis:  # align with (..., S, H, D)
        cos, sin = cos[:, None, :], sin[:, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def mlp_init(key, d_model: int, d_ff: int, dtype=DTYPE) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def scan_layers(body, carry, xs, unroll: bool = False):
    """lax.scan over stacked layer params, or a python unroll.

    The unrolled form exists for the roofline costing path:
    ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
    trip count (verified empirically — see launch/costing.py), so FLOP/byte
    calibration lowers small-L unrolled variants and extrapolates.
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, ignore_id: int = -1
) -> jax.Array:
    """Mean token cross-entropy; logits (..., V) f32-upcast reduction."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    mask = labels != ignore_id
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def chunked_cross_entropy(
    hidden: jax.Array,  # (B, S, d) final hidden states (already normed)
    head: jax.Array,  # (d, V) unembedding
    labels: jax.Array,  # (B, S) — position t is the target FOR hidden[t]
    ignore_id: int = -1,
    chunk: int = 256,
) -> jax.Array:
    """Fused next-token CE: never materializes (B, S, V) logits.

    §Perf iteration B1: scans over sequence chunks; per chunk the (B, c, V)
    logits exist only inside the fused logsumexp/select reductions, with
    the vocab axis left sharded (the gold logit is picked by an iota
    compare + masked reduce — local on every vocab shard, no gather).
    Activation memory drops from O(B·S·V) f32 to O(B·chunk·V); the vocab
    all-gather of the unfused path disappears.
    """
    b, s, d = hidden.shape
    v = head.shape[1]
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=ignore_id)
    hc = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # recompute chunk logits in backward: residuals are O(B·c)
    def one(carry, xs):
        nll_sum, count = carry
        h, lab = xs
        logits = jnp.einsum("bcd,dv->bcv", h, head).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(
            jnp.where(iota == lab[..., None], logits, 0.0), axis=-1
        )
        mask = lab != ignore_id
        nll_sum = nll_sum + jnp.sum((lse - gold) * mask)
        count = count + jnp.sum(mask)
        return (nll_sum, count), None

    (nll_sum, count), _ = jax.lax.scan(one, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return nll_sum / jnp.maximum(count, 1)
