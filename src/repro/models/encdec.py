"""Encoder-decoder backbone (whisper-base).

Frontend is a STUB per the brief: `input_specs()` supplies precomputed
(B, S, d_model) frame embeddings (the conv1d×2 + sinusoidal-position stack
is out of scope).  Encoder = bidirectional transformer; decoder = causal
self-attention + cross-attention to encoder states.

Serving: prefill runs the encoder once and caches (a) decoder self-attn
K/V and (b) cross-attn K/V (computed once from encoder output); decode
steps touch only those caches — the encoder is never re-run.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    _chunked_attention,
    _decode_attention,
    _split_heads,
    gqa_init,
)
from .layers import DTYPE, apply_rope, dense_init, embed_init, mlp_init, rms_norm, scan_layers, swiglu
from ..parallel.sharding import shard

Params = Dict[str, Any]


def _xattn_init(key, cfg, dtype=DTYPE) -> Params:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dtype),
    }


def encdec_init(key, cfg, dtype=DTYPE) -> Params:
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": gqa_init(k1, cfg, dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": gqa_init(k1, cfg, dtype),
            "x_norm": jnp.ones((cfg.d_model,), dtype),
            "xattn": _xattn_init(k2, cfg, dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
        }

    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_layers": jax.tree.map(lambda *x: jnp.stack(x), *[enc_layer(k) for k in enc_keys]),
        "dec_layers": jax.tree.map(lambda *x: jnp.stack(x), *[dec_layer(k) for k in dec_keys]),
        "embed": embed_init(ks[2], cfg.vocab, cfg.d_model, dtype),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab, dtype),
    }


def _self_attn(p, x, cfg, positions, causal, cache=None, pos=None, return_kv=False):
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    b, s, _ = x.shape
    scale = 1.0 / math.sqrt(hd)
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), cfg.n_heads, hd)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wk"]), kvh, hd)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wv"]), kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    qg = q.reshape(b, s, kvh, g, hd)
    if cache is None:
        out = _chunked_attention(qg, k, v, scale, causal=causal)
        kv = (k, v) if return_kv else None
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        valid = jnp.arange(ck.shape[1]) <= pos
        out = _decode_attention(qg, ck, cv, scale, valid)
        kv = {"k": ck, "v": cv}
    out = out.reshape(b, s, cfg.n_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), kv


def _cross_attn(p, x, cfg, enc_kv):
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    b, s, _ = x.shape
    scale = 1.0 / math.sqrt(hd)
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), cfg.n_heads, hd)
    qg = q.reshape(b, s, kvh, g, hd)
    k, v = enc_kv["k"], enc_kv["v"]
    out = _chunked_attention(qg, k, v, scale, causal=False)
    out = out.reshape(b, s, cfg.n_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def encode(p: Params, frames: jax.Array, cfg) -> jax.Array:
    """frames: (B, S_enc, d_model) stubbed frontend output → encoder states."""
    x = shard(frames.astype(p["enc_norm"].dtype), ("batch", "seq", None))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, lp):
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        a, _ = _self_attn(lp["attn"], h, cfg, positions, causal=False)
        x = x + a
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + swiglu(h, **lp["mlp"])
        return x, None

    x, _ = scan_layers(jax.checkpoint(body), x, p["enc_layers"], cfg.unroll_layers)
    return rms_norm(x, p["enc_norm"], cfg.norm_eps)


def _enc_cross_kv(p_dec_layers, enc_out, cfg):
    """Per-decoder-layer cross K/V from encoder output (computed once)."""
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads

    def one(lp):
        k = _split_heads(jnp.einsum("bsd,dh->bsh", enc_out, lp["xattn"]["wk"]), kvh, hd)
        v = _split_heads(jnp.einsum("bsd,dh->bsh", enc_out, lp["xattn"]["wv"]), kvh, hd)
        return {"k": k, "v": v}

    return jax.lax.map(one, p_dec_layers)


def decode_forward(
    p: Params,
    tokens: jax.Array,
    enc_out: jax.Array,
    cfg,
    *,
    remat: bool = True,
    return_hidden: bool = False,
) -> jax.Array:
    """Teacher-forced decoder pass → logits (B, S_dec, V)."""
    x = jnp.take(p["embed"], tokens, axis=0)
    x = shard(x, ("batch", "seq", None))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, lp):
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        a, _ = _self_attn(lp["attn"], h, cfg, positions, causal=True)
        x = x + a
        h = rms_norm(x, lp["x_norm"], cfg.norm_eps)
        kv = {
            "k": _split_heads(
                jnp.einsum("bsd,dh->bsh", enc_out, lp["xattn"]["wk"]),
                cfg.n_kv_heads, cfg.resolved_head_dim,
            ),
            "v": _split_heads(
                jnp.einsum("bsd,dh->bsh", enc_out, lp["xattn"]["wv"]),
                cfg.n_kv_heads, cfg.resolved_head_dim,
            ),
        }
        x = x + _cross_attn(lp["xattn"], h, cfg, kv)
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + swiglu(h, **lp["mlp"])
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = scan_layers(body, x, p["dec_layers"], cfg.unroll_layers)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return shard(jnp.einsum("bsd,dv->bsv", x, p["lm_head"]), ("batch", "seq", "vocab"))


def encdec_forward(p, frames, tokens, cfg, return_hidden: bool = False) -> jax.Array:
    """Training path: encoder + teacher-forced decoder → logits."""
    return decode_forward(
        p, tokens, encode(p, frames, cfg), cfg, return_hidden=return_hidden
    )


def encdec_prefill(p, frames, tokens, cfg):
    """Serving prefill → (last logits (B,V), cache).

    Cache = decoder self-attn K/V (written up to S_dec) + cross K/V.
    """
    enc_out = encode(p, frames, cfg)
    x = jnp.take(p["embed"], tokens, axis=0)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    cross_kv = _enc_cross_kv(p["dec_layers"], enc_out, cfg)

    def body(x, scanned):
        lp, xkv = scanned
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        a, kv = _self_attn(lp["attn"], h, cfg, positions, causal=True, return_kv=True)
        x = x + a
        h = rms_norm(x, lp["x_norm"], cfg.norm_eps)
        x = x + _cross_attn(lp["xattn"], h, cfg, xkv)
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + swiglu(h, **lp["mlp"])
        return x, {"k": kv[0], "v": kv[1]}

    x, self_kv = scan_layers(body, x, (p["dec_layers"], cross_kv), cfg.unroll_layers)
    x = rms_norm(x[:, -1:], p["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])[:, 0]
    return logits, {"self": self_kv, "cross": cross_kv}


def encdec_decode_step(p, cache, tokens, pos, cfg):
    """One decoder step against the (self, cross) caches → (logits, cache)."""
    x = jnp.take(p["embed"], tokens[:, None], axis=0)
    positions = jnp.full((1,), pos, jnp.int32)

    def body(x, scanned):
        lp, skv, xkv = scanned
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        a, new_skv = _self_attn(
            lp["attn"], h, cfg, positions, causal=True, cache=skv, pos=pos
        )
        x = x + a
        h = rms_norm(x, lp["x_norm"], cfg.norm_eps)
        x = x + _cross_attn(lp["xattn"], h, cfg, xkv)
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + swiglu(h, **lp["mlp"])
        return x, new_skv

    x, new_self = scan_layers(body, x, (p["dec_layers"], cache["self"], cache["cross"]), cfg.unroll_layers)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])[:, 0]
    return logits, {"self": new_self, "cross": cache["cross"]}


def encdec_cache_spec(cfg, batch: int, seq_len: int, enc_len: int, dtype=DTYPE):
    hd = cfg.resolved_head_dim
    kv = lambda s: {
        "k": jax.ShapeDtypeStruct((cfg.n_layers, batch, s, cfg.n_kv_heads, hd), dtype),
        "v": jax.ShapeDtypeStruct((cfg.n_layers, batch, s, cfg.n_kv_heads, hd), dtype),
    }
    return {"self": kv(seq_len), "cross": kv(enc_len)}
