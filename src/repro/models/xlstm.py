"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential recurrence) — Beck et al. 2024 (arXiv:2405.04517).

mLSTM is a gated linear-attention form: C_t = f_t C_{t-1} + i_t v_t k_tᵀ,
h_t = o_t ⊙ (C_t q_t / max(|n_t·q_t|, 1)), with exponential-gating
stabilizer m_t.  We implement the exact chunkwise form (weak memory in
chunk index, like SSD) — cross-chunk state (nh, dv, dk) carried by a scan.

sLSTM keeps a true nonlinear recurrence (per-head scalar memory) and runs
as a lax.scan over time — the one assigned mixer that is NOT
chunk-parallelizable (noted in DESIGN.md §Arch-applicability).

Block layout follows the paper's pre-up-projection variant for mLSTM
(d_ff = 0 in the assigned config: the block carries its own 2× up/down
projections) and post-FFN-free sLSTM block.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, dense_init, rms_norm
from ..parallel.sharding import shard

Params = Dict[str, Any]


# ------------------------------------------------------------ mLSTM ----


def mlstm_init(key, cfg, dtype=DTYPE) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    d_in = 2 * d  # pre-up-projection ×2
    hd = d_in // nh
    ks = jax.random.split(key, 5)
    return {
        "up_proj": dense_init(ks[0], d, 2 * d_in, dtype),  # [x_mlstm, z_gate]
        "w_qkv": dense_init(ks[1], d_in, 3 * d_in, dtype),
        "w_if": dense_init(ks[2], d_in, 2 * nh, dtype),  # input/forget gates
        "gate_norm": jnp.ones((d_in,), dtype),
        "down_proj": dense_init(ks[3], d_in, d, dtype),
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i, h0, n0, m0, chunk):
    """Exact chunkwise mLSTM.

    q,k,v: (B,S,nh,hd);  log_f,log_i: (B,S,nh);  state:
      h0 (B,nh,hd,hd)  matrix memory C,
      n0 (B,nh,hd)     normalizer,
      m0 (B,nh)        max-stabilizer.
    """
    b, s, nh, hd = q.shape
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, nh, hd)
    kc = k.reshape(b, nc, chunk, nh, hd)
    vc = v.reshape(b, nc, chunk, nh, hd)
    lf = log_f.reshape(b, nc, chunk, nh)
    li = log_i.reshape(b, nc, chunk, nh)
    cum_f = jnp.cumsum(lf, axis=2)  # inclusive

    def body(carry, inp):
        C, n, m = carry  # (b,nh,hd,hd), (b,nh,hd), (b,nh)
        qk, kk, vk, cf, lik = inp
        # intra-chunk kernel: D[l,s] = exp(cf[l]-cf[s]+li[s]) for s≤l
        # (cf = inclusive within-chunk cumsum of log forget gates)
        # log weights of source s for target l
        logw = cf[:, :, None, :] - cf[:, None, :, :] + lik[:, None, :, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
        logw = jnp.where(causal[None, :, :, None] > 0, logw, -jnp.inf)
        # cross-chunk contribution decay for target l: exp(cf[l]) relative to m
        log_cross = cf + m[:, None, :]  # (b,l,nh)
        m_new = jnp.maximum(
            jnp.max(jnp.where(jnp.isfinite(logw), logw, -jnp.inf), axis=2),
            log_cross,
        )  # (b,l,nh)
        w = jnp.exp(logw - m_new[:, :, None, :])  # (b,l,s,nh)
        cross_scale = jnp.exp(log_cross - m_new)  # (b,l,nh)

        scores = jnp.einsum("blhd,bshd->blsh", qk, kk) * (qk.shape[-1] ** -0.5)
        intra = jnp.einsum("blsh,blsh,bshd->blhd", scores, w, vk)
        inter = jnp.einsum("blhd,bhed->blhe", qk, C) * (
            qk.shape[-1] ** -0.5
        ) * cross_scale[..., None]
        num = intra + inter
        den_intra = jnp.einsum("blsh,blsh->blh", scores, w)
        den_inter = jnp.einsum("blhd,bhd->blh", qk, n) * (
            qk.shape[-1] ** -0.5
        ) * cross_scale
        den = jnp.abs(den_intra + den_inter)
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]

        # chunk-end state update
        tot_f = cf[:, -1]  # (b,nh)
        m_next = jnp.maximum(tot_f + m, jnp.max(tot_f[:, None] - cf + lik, axis=1))
        carry_scale = jnp.exp(tot_f + m - m_next)
        src_w = jnp.exp(tot_f[:, None] - cf + lik - m_next[:, None])  # (b,s,nh)
        C_new = C * carry_scale[..., None, None] + jnp.einsum(
            "bshd,bshe,bsh->bhde", vk, kk, src_w
        )
        n_new = n * carry_scale[..., None] + jnp.einsum("bshd,bsh->bhd", kk, src_w)
        return (C_new, n_new, m_next), h

    inputs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, cum_f, li)
    )
    (C, n, m), hs = jax.lax.scan(body, (h0, n0, m0), inputs)
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh, hd)
    return h, (C, n, m)


def mlstm_apply(
    p: Params,
    x: jax.Array,
    cfg,
    *,
    state: Optional[Params] = None,
    return_state: bool = False,
    chunk: int = 64,
) -> Tuple[jax.Array, Optional[Params]]:
    b, s, d = x.shape
    nh = cfg.n_heads
    d_in = 2 * d
    hd = d_in // nh

    up = jnp.einsum("bsd,dh->bsh", x, p["up_proj"])
    up = shard(up, ("batch", None, "ff"))
    xm, z = jnp.split(up, 2, axis=-1)
    qkv = jnp.einsum("bsh,hk->bsk", xm, p["w_qkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nh, hd)
    v = v.reshape(b, s, nh, hd)
    gates = jnp.einsum("bsh,hg->bsg", xm, p["w_if"]).astype(jnp.float32)
    log_i, f_raw = jnp.split(gates, 2, axis=-1)  # (B,S,nh) each
    log_f = jax.nn.log_sigmoid(f_raw)

    if state is None:
        C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        m0 = jnp.full((b, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    if s == 1:
        # O(1) recurrence
        lf, li_ = log_f[:, 0], log_i[:, 0]
        m_new = jnp.maximum(lf + m0, li_)
        C = C0 * jnp.exp(lf + m0 - m_new)[..., None, None] + jnp.exp(
            li_ - m_new
        )[..., None, None] * jnp.einsum("bhd,bhe->bhde", v[:, 0], k[:, 0])
        n = n0 * jnp.exp(lf + m0 - m_new)[..., None] + jnp.exp(li_ - m_new)[
            ..., None
        ] * k[:, 0]
        qs = q[:, 0] * (hd**-0.5)
        num = jnp.einsum("bhd,bhed->bhe", qs, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n))
        h = (num / jnp.maximum(den, jnp.exp(-m_new))[..., None])[:, None]
        new_state = {"C": C, "n": n, "m": m_new}
    else:
        chunk = min(chunk, s)
        s_orig = s
        pad = (-s) % chunk
        if pad:
            # padded steps: log_f = 0 (no decay), log_i = -1e30 (no input) —
            # exact identities in the recurrence.
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
            log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        h, (C, n, m) = _mlstm_chunk_scan(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            log_f, log_i, C0, n0, m0, chunk,
        )
        h = h[:, :s_orig]
        new_state = {"C": C, "n": n, "m": m}

    h = h.reshape(b, -1, d_in).astype(x.dtype)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    h = rms_norm(h, p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsh,hd->bsd", h, p["down_proj"])
    return out, (new_state if (return_state or state is not None) else None)


def mlstm_state_spec(cfg, batch: int) -> Dict[str, Any]:
    nh = cfg.n_heads
    hd = 2 * cfg.d_model // nh
    return {
        "C": jax.ShapeDtypeStruct((batch, nh, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
    }


# ------------------------------------------------------------ sLSTM ----


def slstm_init(key, cfg, dtype=DTYPE) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ks = jax.random.split(key, 4)
    return {
        # input-driven gates+cell (i, f, z, o) and recurrent (block-diag per head)
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),
        "r_gates": (jax.random.normal(ks[1], (nh, hd, 4 * hd)) * (hd**-0.5)).astype(
            dtype
        ),
        "gate_norm": jnp.ones((d,), dtype),
        "up_proj": dense_init(ks[2], d, 2 * cfg.d_model, dtype),
        "down_proj": dense_init(ks[3], 2 * cfg.d_model, d, dtype),
    }


def slstm_apply(
    p: Params,
    x: jax.Array,
    cfg,
    *,
    state: Optional[Params] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    """sLSTM with exponential gating and per-head recurrence (scan over S)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh

    wx = jnp.einsum("bsd,dh->bsh", x, p["w_gates"]).reshape(b, s, nh, 4 * hd)
    if state is None:
        h0 = jnp.zeros((b, nh, hd), jnp.float32)
        c0 = jnp.zeros((b, nh, hd), jnp.float32)
        n0 = jnp.ones((b, nh, hd), jnp.float32)
        m0 = jnp.zeros((b, nh, hd), jnp.float32)
    else:
        h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]

    r = p["r_gates"].astype(jnp.float32)

    def step(carry, wx_t):
        h, c, n, m = carry
        pre = wx_t.astype(jnp.float32) + jnp.einsum("bhd,hdk->bhk", h, r)
        i_, f_, z_, o_ = jnp.split(pre, 4, axis=-1)
        log_f = jax.nn.log_sigmoid(f_)
        m_new = jnp.maximum(log_f + m, i_)
        i_g = jnp.exp(i_ - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(z_)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_) * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0), jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, p["gate_norm"], cfg.norm_eps)
    # position-wise FFN inside the block (d_ff = 0 in config → ×2 internal)
    u = jnp.einsum("bsd,dh->bsh", y, p["up_proj"])
    u = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", u, p["down_proj"])
    new_state = {"h": h, "c": c, "n": n, "m": m}
    return out, (new_state if (return_state or state is not None) else None)


def slstm_state_spec(cfg, batch: int) -> Dict[str, Any]:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    sds = lambda: jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32)
    return {"h": sds(), "c": sds(), "n": sds(), "m": sds()}
