"""VLM / audio modality frontend STUBS (per the brief).

``[audio]`` / ``[vlm]`` cells specify the transformer backbone only; the
frontend is replaced by precomputed embeddings supplied via input_specs():

  llava-next-34b : anyres tiling → patch embeddings (B, n_patches, d_model).
    The real frontend (CLIP-ViT + 2-layer MLP projector + anyres grid
    selection) is summarized by `fake_patch_embeds`, which reproduces only
    its OUTPUT CONTRACT (count, dtype, scale).
  whisper-base   : log-mel + conv1d×2 (stride 2) → frame embeddings
    (B, S, d_model) via `fake_frame_embeds`.

These exist so examples/tests can run end-to-end without image/audio data;
the dry-run uses ShapeDtypeStructs and never calls them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import DTYPE


def fake_patch_embeds(key, batch: int, n_patches: int, d_model: int, dtype=DTYPE):
    """Stand-in for the anyres vision tower output (unit-scale embeddings)."""
    return jax.random.normal(key, (batch, n_patches, d_model)).astype(dtype)


def fake_frame_embeds(key, batch: int, n_frames: int, d_model: int, dtype=DTYPE):
    """Stand-in for the whisper conv frontend output."""
    return jax.random.normal(key, (batch, n_frames, d_model)).astype(dtype)
