"""Decoder-only LM backbone (dense / MoE / VLM families).

Layers are homogeneous → parameters are stacked on a leading L axis and the
stack is traversed with jax.lax.scan (one compiled layer body regardless of
depth: compile time and HLO size are O(1) in n_layers).  Remat policy wraps
the scanned body.

Three entry points (all pure):
  lm_forward      — tokens → logits               (training loss path)
  lm_prefill      — tokens → (last logits, cache) (serving prefill)
  lm_decode_step  — (cache, token, pos) → (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention_apply, attention_cache_spec, attention_init
from .layers import DTYPE, dense_init, embed_init, mlp_init, rms_norm, scan_layers, swiglu
from .moe import moe_apply, moe_init
from ..parallel.sharding import shard

Params = Dict[str, Any]


def _remat_policy(cfg):
    """'full' → recompute-all; 'dots' → keep matmul outputs (§Perf A3:
    trades activation memory for ~the remat recompute flops)."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


# ------------------------------------------------------------- init ----


def _layer_init(key, cfg, dtype=DTYPE) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": attention_init(k1, cfg, dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def lm_init(key, cfg, dtype=DTYPE) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = [_layer_init(ks[i], cfg, dtype) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    p: Params = {
        "embed": embed_init(ks[-3], cfg.vocab, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[-2], cfg.d_model, cfg.vocab, dtype)
    if cfg.family == "vlm":
        p["patch_proj"] = dense_init(ks[-1], cfg.d_model, cfg.d_model, dtype)
    return p


# ------------------------------------------------------------ blocks ---


def _block(p: Params, x, cfg, positions, cache=None, pos=None, return_cache=False):
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    attn_out, new_cache = attention_apply(
        p["attn"], h, cfg, positions, cache=cache, pos=pos, return_cache=return_cache
    )
    x = x + attn_out
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.moe is not None:
        mlp_out, aux = moe_apply(p["moe"], h, cfg)
    else:
        mlp_out = swiglu(h, **p["mlp"])
        aux = {
            "lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
        }
    x = x + mlp_out
    # §Perf B5 (opt-in): sequence-shard the residual over the model axis —
    # GSPMD then reduce-scatters the TP partial sums instead of all-reducing.
    seq_axis = "seq_tp" if (cfg.seq_parallel_residual and cache is None) else "seq"
    x = shard(x, ("batch", seq_axis, None))
    return x, new_cache, aux


def _embed_inputs(p: Params, cfg, tokens, patch_embeds=None):
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.family == "vlm":
        assert patch_embeds is not None, "vlm arch needs patch_embeds"
        patches = jnp.einsum("bpd,de->bpe", patch_embeds.astype(x.dtype), p["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)  # anyres patches prefix text
    return shard(x, ("batch", "seq", None))


def _unembed(p: Params, cfg, x):
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------- forward ----


def lm_forward(
    p: Params,
    tokens: jax.Array,  # (B, S_text)
    cfg,
    *,
    patch_embeds: Optional[jax.Array] = None,
    remat: bool = True,
    return_hidden: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward → (logits (B, S_total, V), aux losses).

    ``return_hidden=True`` returns the final normed hidden states instead of
    logits (the fused-loss path, §Perf B1).
    """
    x = _embed_inputs(p, cfg, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, layer_p):
        x, _, aux = _block(layer_p, x, cfg, positions)
        return x, aux

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, auxs = scan_layers(body, x, p["layers"], cfg.unroll_layers)
    aux = jax.tree.map(jnp.sum, auxs)
    if return_hidden:
        return rms_norm(x, p["final_norm"], cfg.norm_eps), aux
    return _unembed(p, cfg, x), aux


def lm_head_matrix(p: Params, cfg) -> jax.Array:
    return p["embed"].T if cfg.tie_embeddings else p["lm_head"]


def lm_prefill(
    p: Params,
    tokens: jax.Array,
    cfg,
    *,
    patch_embeds: Optional[jax.Array] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Any]:
    """Prefill → (logits of the last position (B, V), stacked cache (L, …))."""
    x = _embed_inputs(p, cfg, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, layer_p):
        x, cache, _ = _block(layer_p, x, cfg, positions, return_cache=True)
        return x, cache

    if remat:
        body = jax.checkpoint(body)
    x, caches = scan_layers(body, x, p["layers"], cfg.unroll_layers)
    logits = _unembed(p, cfg, x[:, -1:, :])[:, 0]
    return logits, caches


def lm_decode_step(
    p: Params,
    cache: Any,  # stacked (L, …) pytree
    tokens: jax.Array,  # (B,) next token ids
    pos: jax.Array,  # scalar int32 — write position (== #valid entries)
    cfg,
) -> Tuple[jax.Array, Any]:
    """One decode step → (logits (B, V), updated cache)."""
    x = jnp.take(p["embed"], tokens[:, None], axis=0)
    x = shard(x, ("batch", None, None))
    positions = jnp.full((1,), pos, jnp.int32)

    def body(x, scanned):
        layer_p, layer_cache = scanned
        x, new_cache, _ = _block(layer_p, x, cfg, positions, cache=layer_cache, pos=pos)
        return x, new_cache

    x, new_caches = scan_layers(body, x, (p["layers"], cache), cfg.unroll_layers)
    logits = _unembed(p, cfg, x)[:, 0]
    return logits, new_caches


def lm_cache_spec(cfg, batch: int, seq_len: int, dtype=DTYPE):
    """Stacked (L, …) ShapeDtypeStructs for the decode cache."""
    per_layer = attention_cache_spec(cfg, batch, seq_len, dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype), per_layer
    )
