"""Model zoo dispatcher: one uniform API over all assigned architectures.

  init_params(key, cfg)                  → params pytree
  forward(params, batch, cfg)            → (logits, aux)   [training]
  prefill(params, batch, cfg)            → (last logits, cache)
  decode_step(params, cache, batch, cfg) → (logits, cache)
  cache_spec(cfg, batch, seq)            → ShapeDtypeStruct pytree
  input_specs(cfg, shape)                → dry-run input ShapeDtypeStructs

``batch`` is a dict; its keys depend on family (brief: modality frontends
are stubs — VLM supplies ``patch_embeds``, whisper supplies ``frames``).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import encdec, transformer, xlstm_lm, zamba
from .layers import DTYPE
from ..configs.base import ArchConfig, ShapeConfig

Params = Dict[str, Any]

_ZERO_AUX = lambda: {
    "lb_loss": jnp.zeros((), jnp.float32),
    "z_loss": jnp.zeros((), jnp.float32),
}


def init_params(key, cfg: ArchConfig, dtype=DTYPE) -> Params:
    if cfg.family == "encdec":
        return encdec.encdec_init(key, cfg, dtype)
    if cfg.family == "hybrid":
        return zamba.zamba_init(key, cfg, dtype)
    if cfg.family == "ssm":
        return xlstm_lm.xlstm_lm_init(key, cfg, dtype)
    return transformer.lm_init(key, cfg, dtype)


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig):
    """Training forward → (logits over the full target sequence, aux)."""
    if cfg.family == "encdec":
        logits = encdec.encdec_forward(params, batch["frames"], batch["tokens"], cfg)
        return logits, _ZERO_AUX()
    if cfg.family == "hybrid":
        return zamba.zamba_forward(params, batch["tokens"], cfg), _ZERO_AUX()
    if cfg.family == "ssm":
        return xlstm_lm.xlstm_forward(params, batch["tokens"], cfg), _ZERO_AUX()
    logits, aux = transformer.lm_forward(
        params, batch["tokens"], cfg, patch_embeds=batch.get("patch_embeds")
    )
    return logits, aux


def forward_hidden(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig):
    """Training forward stopping at the final hidden states (fused-loss path,
    §Perf B1) → (hidden (B, S, d), head (d, V), aux)."""
    if cfg.family == "encdec":
        h = encdec.encdec_forward(
            params, batch["frames"], batch["tokens"], cfg, return_hidden=True
        )
        return h, params["lm_head"], _ZERO_AUX()
    if cfg.family == "hybrid":
        h = zamba.zamba_forward(params, batch["tokens"], cfg, return_hidden=True)
        return h, params["lm_head"], _ZERO_AUX()
    if cfg.family == "ssm":
        h = xlstm_lm.xlstm_forward(params, batch["tokens"], cfg, return_hidden=True)
        return h, params["lm_head"], _ZERO_AUX()
    h, aux = transformer.lm_forward(
        params, batch["tokens"], cfg,
        patch_embeds=batch.get("patch_embeds"), return_hidden=True,
    )
    return h, transformer.lm_head_matrix(params, cfg), aux


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig):
    if cfg.family == "encdec":
        return encdec.encdec_prefill(params, batch["frames"], batch["tokens"], cfg)
    if cfg.family == "hybrid":
        return zamba.zamba_prefill(params, batch["tokens"], cfg)
    if cfg.family == "ssm":
        return xlstm_lm.xlstm_prefill(params, batch["tokens"], cfg)
    return transformer.lm_prefill(
        params, batch["tokens"], cfg, patch_embeds=batch.get("patch_embeds")
    )


def decode_step(params: Params, cache, batch: Dict[str, jax.Array], cfg: ArchConfig):
    tokens, pos = batch["tokens"], batch["pos"]
    if cfg.family == "encdec":
        return encdec.encdec_decode_step(params, cache, tokens, pos, cfg)
    if cfg.family == "hybrid":
        return zamba.zamba_decode_step(params, cache, tokens, pos, cfg)
    if cfg.family == "ssm":
        return xlstm_lm.xlstm_decode_step(params, cache, tokens, pos, cfg)
    return transformer.lm_decode_step(params, cache, tokens, pos, cfg)


def cache_spec(cfg: ArchConfig, batch: int, seq_len: int, dtype=DTYPE):
    if cfg.family == "encdec":
        return encdec.encdec_cache_spec(cfg, batch, seq_len, enc_len=seq_len, dtype=dtype)
    if cfg.family == "hybrid":
        return zamba.zamba_cache_spec(cfg, batch, seq_len, dtype)
    if cfg.family == "ssm":
        return xlstm_lm.xlstm_cache_spec(cfg, batch, seq_len, dtype)
    return transformer.lm_cache_spec(cfg, batch, seq_len, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    Weak-type-correct, shardable, no device allocation (brief requirement).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs: Dict[str, Any] = {}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), DTYPE)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        elif cfg.family == "vlm":
            st = s - cfg.n_patches
            specs["patch_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), DTYPE)
            specs["tokens"] = jax.ShapeDtypeStruct((b, st), i32)
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs
    if shape.kind == "prefill":
        specs = {}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), DTYPE)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        elif cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), DTYPE)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.n_patches), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs
    # decode: one new token against a seq_len cache
    return {
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache_spec(cfg, b, s),
    }
