"""xLSTM language model: alternating mLSTM / sLSTM blocks (xlstm-125m).

With ``slstm_every = 2`` the 12-layer stack is 6 scanned super-blocks of
(mLSTM → sLSTM); recurrent state (not a KV cache) makes every decode shape
O(1) in context — long_500k runs trivially (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, dense_init, embed_init, rms_norm, scan_layers
from .xlstm import (
    mlstm_apply,
    mlstm_init,
    mlstm_state_spec,
    slstm_apply,
    slstm_init,
    slstm_state_spec,
)
from ..parallel.sharding import shard

Params = Dict[str, Any]


def _n_pairs(cfg) -> int:
    if cfg.slstm_every:
        assert cfg.n_layers % 2 == 0, "alternating stack needs even n_layers"
        return cfg.n_layers // 2
    return cfg.n_layers


def xlstm_lm_init(key, cfg, dtype=DTYPE) -> Params:
    np_ = _n_pairs(cfg)
    ks = jax.random.split(key, np_ + 2)

    def pair(k):
        k1, k2 = jax.random.split(k)
        p = {"m_norm": jnp.ones((cfg.d_model,), dtype), "mlstm": mlstm_init(k1, cfg, dtype)}
        if cfg.slstm_every:
            p["s_norm"] = jnp.ones((cfg.d_model,), dtype)
            p["slstm"] = slstm_init(k2, cfg, dtype)
        return p

    pairs = [pair(ks[i]) for i in range(np_)]
    return {
        "embed": embed_init(ks[-2], cfg.vocab, cfg.d_model, dtype),
        "pairs": jax.tree.map(lambda *x: jnp.stack(x), *pairs),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[-1], cfg.d_model, cfg.vocab, dtype),
    }


def _pair_apply(lp, x, cfg, states=None, return_state=False):
    h = rms_norm(x, lp["m_norm"], cfg.norm_eps)
    m_out, m_state = mlstm_apply(
        lp["mlstm"], h, cfg,
        state=None if states is None else states["m"],
        return_state=return_state,
    )
    x = x + m_out
    s_state = None
    if cfg.slstm_every:
        h = rms_norm(x, lp["s_norm"], cfg.norm_eps)
        s_out, s_state = slstm_apply(
            lp["slstm"], h, cfg,
            state=None if states is None else states["s"],
            return_state=return_state,
        )
        x = x + s_out
    new_states = None
    if return_state or states is not None:
        new_states = {"m": m_state} | ({"s": s_state} if cfg.slstm_every else {})
    return x, new_states


def xlstm_forward(
    p: Params, tokens: jax.Array, cfg, *, remat: bool = True,
    return_hidden: bool = False,
) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0)
    x = shard(x, ("batch", "seq", None))

    def body(x, lp):
        x, _ = _pair_apply(lp, x, cfg)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = scan_layers(body, x, p["pairs"], cfg.unroll_layers)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return shard(jnp.einsum("bsd,dv->bsv", x, p["lm_head"]), ("batch", "seq", "vocab"))


def xlstm_prefill(p: Params, tokens: jax.Array, cfg):
    x = jnp.take(p["embed"], tokens, axis=0)

    def body(x, lp):
        x, st = _pair_apply(lp, x, cfg, return_state=True)
        return x, st

    x, states = scan_layers(body, x, p["pairs"], cfg.unroll_layers)
    x = rms_norm(x[:, -1:], p["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])[:, 0]
    return logits, states


def xlstm_decode_step(p: Params, states, tokens: jax.Array, pos, cfg):
    x = jnp.take(p["embed"], tokens[:, None], axis=0)

    def body(x, scanned):
        lp, st = scanned
        x, new_st = _pair_apply(lp, x, cfg, states=st)
        return x, new_st

    x, new_states = scan_layers(body, x, (p["pairs"], states), cfg.unroll_layers)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])[:, 0]
    return logits, new_states


def xlstm_cache_spec(cfg, batch: int, seq_len: int, dtype=DTYPE):
    np_ = _n_pairs(cfg)
    per = {"m": mlstm_state_spec(cfg, batch)}
    if cfg.slstm_every:
        per["s"] = slstm_state_spec(cfg, batch)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((np_,) + s.shape, s.dtype), per
    )
