"""Attention: GQA / MLA / sliding-window, train + prefill + decode paths.

Memory discipline: logits are never materialized at (S × S) — queries are
processed in chunks (lax.map), bounding the live buffer at (chunk × S_k).
For sliding-window attention the key slice per chunk is (window + chunk) —
the paper's weak-memory window (halo) at the XLA level; the Pallas kernel
`repro.kernels.swa_attention` is the explicitly-tiled forward twin.

Decode uses a static-capacity cache written in place at position ``pos``
(dynamic_update_slice), masked by entry validity.  SWA decode uses a ring
cache of capacity min(window, seq) with explicit position tracking.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, apply_rope, dense_init, rms_norm
from ..parallel.sharding import shard

Params = Dict[str, Any]


def _model_axis_size() -> int:
    # version-portable active-mesh lookup (jax.sharding.get_abstract_mesh
    # does not exist on JAX 0.4.x) — shared with the sharding-rule resolver
    from ..parallel.sharding import _active_mesh

    m = _active_mesh()
    if m is None:
        return 1
    return dict(m.shape).get("model", 1)


# ---------------------------------------------------------------- init --


def gqa_init(key, cfg, dtype=DTYPE) -> Params:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def mla_init(key, cfg, dtype=DTYPE) -> Params:
    m = cfg.mla
    ks = jax.random.split(key, 8)
    qdim = cfg.n_heads * (m.nope_head_dim + m.rope_head_dim)
    p = {
        "w_dkv": dense_init(ks[0], cfg.d_model, m.kv_lora_rank, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[1], m.kv_lora_rank, cfg.n_heads * m.nope_head_dim, dtype),
        "w_uv": dense_init(ks[2], m.kv_lora_rank, cfg.n_heads * m.v_head_dim, dtype),
        "w_kr": dense_init(ks[3], cfg.d_model, m.rope_head_dim, dtype),
        "wo": dense_init(ks[4], cfg.n_heads * m.v_head_dim, cfg.d_model, dtype),
    }
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[5], cfg.d_model, m.q_lora_rank, dtype)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dtype)
        p["w_uq"] = dense_init(ks[6], m.q_lora_rank, qdim, dtype)
    else:
        p["wq"] = dense_init(ks[7], cfg.d_model, qdim, dtype)
    return p


def attention_init(key, cfg, dtype=DTYPE) -> Params:
    return mla_init(key, cfg, dtype) if cfg.attn == "mla" else gqa_init(key, cfg, dtype)


# ------------------------------------------------------- chunked core --


def _chunked_attention(
    q: jax.Array,  # (B, S, KVH, G, hk)
    k: jax.Array,  # (B, Sk, KVH, hk)
    v: jax.Array,  # (B, Sk, KVH, hv)
    scale: float,
    *,
    q_pos0: int = 0,
    window: Optional[int] = None,
    chunk: int = 512,
    causal: bool = True,
) -> jax.Array:
    """Causal (optionally banded) or bidirectional attention, query-chunked.

    Bounds live logits at (B, chunk, KVH, G, key_width).  ``window=None`` →
    full causal, key_width = Sk; else key slice of width window+chunk (the
    weak-memory halo).  Returns (B, S, KVH, G, hv).
    """
    b, s, kvh, g, hk = q.shape
    sk = k.shape[1]
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))

    use_window = window is not None and sk > window + chunk

    def chunk_fn(i):
        qs = i * chunk
        qc = jax.lax.dynamic_slice_in_dim(q, qs, chunk, axis=1)
        q_pos = q_pos0 + qs + jnp.arange(chunk)
        if use_window:
            width = window + chunk
            start = jnp.clip(qs + q_pos0 - window, 0, sk - width)
            kc = jax.lax.dynamic_slice_in_dim(k, start, width, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, width, axis=1)
            k_pos = start + jnp.arange(width)
        else:
            kc, vc = k, v
            k_pos = jnp.arange(sk)
        logits = jnp.einsum("bqngk,bsnk->bngqs", qc, kc).astype(jnp.float32) * scale
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
        else:
            mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
        if window is not None:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bngqs,bsnv->bqngv", p.astype(v.dtype), vc)
        return out

    outs = jax.lax.map(chunk_fn, jnp.arange(n_chunks))  # (nc, B, chunk, ...)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n_chunks * chunk, kvh, g, v.shape[-1])
    return out[:, :s]


def _decode_attention(
    q: jax.Array,  # (B, 1, KVH, G, hk)
    k: jax.Array,  # (B, C, KVH, hk)
    v: jax.Array,  # (B, C, KVH, hv)
    scale: float,
    valid: jax.Array,  # (C,) or (B, C) bool
) -> jax.Array:
    logits = jnp.einsum("bqngk,bsnk->bngqs", q, k).astype(jnp.float32) * scale
    if valid.ndim == 1:
        valid = valid[None]
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bngqs,bsnv->bqngv", p.astype(v.dtype), v)


# ------------------------------------------------------------- GQA ----


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def gqa_apply(
    p: Params,
    x: jax.Array,  # (B, S, d)
    cfg,
    positions: jax.Array,  # (S,) int32
    *,
    cache: Optional[Params] = None,
    pos: Optional[jax.Array] = None,  # decode write position (scalar)
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    g = cfg.n_heads // kvh
    scale = 1.0 / math.sqrt(hd)
    b, s, _ = x.shape

    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), cfg.n_heads, hd)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wk"]), kvh, hd)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wv"]), kvh, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, ("batch", None, "heads", None))
    k = shard(k, ("batch", None, "heads", None))

    if cache is None:
        # train / prefill over the full (possibly seq-sharded) sequence.
        # §Perf B2: when KVH doesn't divide the model axis but H does, the
        # (KVH, G) head split is unshardable and GSPMD replicates attention
        # (+ all-to-all reshards around it).  Repeating K/V to H heads keeps
        # the whole attention head-sharded: per-device K/V bytes are
        # UNCHANGED (H/ms sharded vs KVH replicated) and the resharding
        # collectives disappear.
        ms = _model_axis_size()
        if ms > 1 and cfg.n_heads % ms == 0 and kvh % ms != 0:
            k_a = jnp.repeat(k, g, axis=2)
            v_a = jnp.repeat(v, g, axis=2)
            k_a = shard(k_a, ("batch", None, "heads", None))
            v_a = shard(v_a, ("batch", None, "heads", None))
            qg = q.reshape(b, s, cfg.n_heads, 1, hd)
        else:
            k_a, v_a = k, v
            qg = q.reshape(b, s, kvh, g, hd)
        out = _chunked_attention(qg, k_a, v_a, scale, window=cfg.swa_window)
        new_cache = None
        if return_cache:
            new_cache = _gqa_fresh_cache(cfg, k, v, positions)
    else:
        # decode: write this token's k/v into the cache, attend over it
        qg = q.reshape(b, s, kvh, g, hd)
        assert s == 1
        if cfg.swa_window is not None and cache["k"].shape[1] <= cfg.swa_window:
            slot = jnp.mod(pos, cache["k"].shape[1])
        else:
            slot = pos
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.full((1,), pos, cache["pos"].dtype), slot, axis=0
        )
        valid = (cpos <= pos) & (cpos >= 0)
        if cfg.swa_window is not None:
            valid &= cpos > pos - cfg.swa_window
        out = _decode_attention(qg, ck, cv, scale, valid)
        new_cache = {"k": ck, "v": cv, "pos": cpos}

    out = out.reshape(b, s, cfg.n_heads * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return out, new_cache


def _gqa_fresh_cache(cfg, k, v, positions):
    """Cache built by prefill.

    SWA archs keep only the trailing window, stored RING-ALIGNED so decode's
    ``slot = pos % window`` convention continues it seamlessly; shorter-than-
    window prefills are padded to full window capacity with invalid slots.
    """
    pos = jnp.broadcast_to(positions, (k.shape[1],)).astype(jnp.int32)
    if cfg.swa_window is not None:
        w = cfg.swa_window
        s = k.shape[1]
        if s > w:
            k, v, pos = k[:, -w:], v[:, -w:], pos[-w:]
            p0 = s - w  # global position of the first kept entry
            shift = p0 % w
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
            pos = jnp.roll(pos, shift, axis=0)
        elif s < w:
            pad = w - s
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pos = jnp.pad(pos, ((0, pad),), constant_values=-1)
    return {"k": k, "v": v, "pos": pos}


def gqa_cache_spec(cfg, batch: int, seq_len: int, dtype=DTYPE) -> Dict[str, Any]:
    """ShapeDtypeStructs for the decode cache (dry-run inputs)."""
    hd = cfg.resolved_head_dim
    c = min(cfg.swa_window, seq_len) if cfg.swa_window is not None else seq_len
    return {
        "k": jax.ShapeDtypeStruct((batch, c, cfg.n_kv_heads, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, c, cfg.n_kv_heads, hd), dtype),
        "pos": jax.ShapeDtypeStruct((c,), jnp.int32),
    }


# ------------------------------------------------------------- MLA ----


def mla_apply(
    p: Params,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    *,
    cache: Optional[Params] = None,
    pos: Optional[jax.Array] = None,
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    """Multi-head latent attention (DeepSeek-V2).

    Two computation forms with identical math (§Perf iteration A1):
      * full-sequence (train/prefill): NON-absorbed — materialize per-head
        k_nope = c_kv·W_uk and v = c_kv·W_uv, score dim 192/head.  The
        matrix-absorbed form costs (kvr+rope)+kvr = 1088 flops per
        (q,k,head) pair vs 192+128 = 320 — 3.4× more on the S² term, which
        dominates training.  Heads shard over "model".
      * decode: ABSORBED — q folded through W_uk so the cache stays the
        compact (c_kv, k_rope) latent and per-step compute is O(H·kvr).
    """
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)

    if m.q_lora_rank:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rh->bsh", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    q = q.reshape(b, s, h, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(jnp.einsum("bsd,dr->bsr", x, p["w_kr"]), positions, cfg.rope_theta)
    kv_lat = jnp.concatenate([c_kv, k_rope], axis=-1)  # (B,S,kvr+rope)

    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)

    if cache is None:
        # non-absorbed: per-head keys/values, heads sharded over "model"
        k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, w_uk)
        k_nope = shard(k_nope, ("batch", None, "heads", None))
        v = jnp.einsum("bsr,rhv->bshv", c_kv, w_uv)
        v = shard(v, ("batch", None, "heads", None))
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.rope_head_dim))],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,192)
        q_full = shard(q_full, ("batch", None, "heads", None))
        qg = q_full.reshape(b, s, h, 1, -1)  # kvh = H, group = 1
        ctx = _chunked_attention(qg, k_full, v, scale, window=cfg.swa_window)
        out = ctx.reshape(b, s, h * m.v_head_dim)
        new_cache = None
        if return_cache:
            new_cache = {
                "lat": kv_lat,
                "pos": jnp.broadcast_to(positions, (s,)).astype(jnp.int32),
            }
    else:
        # absorbed decode against the latent cache
        assert s == 1
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
        q_dec = jnp.concatenate([q_abs, q_rope], axis=-1)  # (B,1,H,kvr+rope)
        lat = jax.lax.dynamic_update_slice_in_dim(cache["lat"], kv_lat, pos, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.full((1,), pos, cache["pos"].dtype), pos, axis=0
        )
        valid = (cpos <= pos) & (cpos >= 0)
        qg = q_dec.reshape(b, 1, 1, h, -1)
        ctx = _decode_attention(
            qg, lat[:, :, None, :], lat[:, :, None, : m.kv_lora_rank], scale, valid
        )
        ctx = ctx.reshape(b, 1, h, m.kv_lora_rank)
        out = jnp.einsum("bshr,rhv->bshv", ctx, w_uv).reshape(b, 1, h * m.v_head_dim)
        new_cache = {"lat": lat, "pos": cpos}

    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return out, new_cache


def mla_cache_spec(cfg, batch: int, seq_len: int, dtype=DTYPE) -> Dict[str, Any]:
    m = cfg.mla
    return {
        "lat": jax.ShapeDtypeStruct(
            (batch, seq_len, m.kv_lora_rank + m.rope_head_dim), dtype
        ),
        "pos": jax.ShapeDtypeStruct((seq_len,), jnp.int32),
    }


# ------------------------------------------------------------ router --


def attention_apply(p, x, cfg, positions, **kw):
    if cfg.attn == "mla":
        return mla_apply(p, x, cfg, positions, **kw)
    return gqa_apply(p, x, cfg, positions, **kw)


def attention_cache_spec(cfg, batch: int, seq_len: int, dtype=DTYPE):
    if cfg.attn == "mla":
        return mla_cache_spec(cfg, batch, seq_len, dtype)
    return gqa_cache_spec(cfg, batch, seq_len, dtype)
