"""Mixture-of-Experts layer: top-k routing, capacity dispatch, EP sharding.

Dispatch/combine use the one-hot einsum formulation (Mesh-TF / GShard
lineage): under GSPMD the expert axis is sharded over "model" (expert
parallelism) and the token axis over "data", so the dispatch einsum lowers
to the canonical all-to-all.  Capacity is static (shape-stable): tokens
overflowing an expert's bucket are dropped (standard Switch behaviour) and
the shared expert(s) (llama4: 1, deepseek-v2: 2) are always-on dense MLPs.

Aux outputs: load-balance loss (Switch §2.2) + router z-loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, dense_init, mlp_init, swiglu
from ..parallel.sharding import shard

Params = Dict[str, Any]


def moe_init(key, cfg, dtype=DTYPE) -> Params:
    m = cfg.moe
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    scale = 1.0 / jnp.sqrt(d)
    p: Params = {
        "router": (jax.random.normal(ks[0], (d, e)) * scale).astype(jnp.float32),
        "e_gate": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dtype),
        "e_up": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(dtype),
        "e_down": (jax.random.normal(ks[3], (e, f, d)) * (1.0 / jnp.sqrt(f))).astype(dtype),
    }
    if m.num_shared:
        p["shared"] = mlp_init(ks[4], d, m.num_shared * f, dtype)
    return p


def moe_apply(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) → (out, aux-losses)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    xt = x.reshape(b * s, d)
    t = xt.shape[0]

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Static capacity; floor of min(t·k, 4) keeps tiny-token decode batches
    # effectively dropless (capacity 1 with colliding routes drops tokens).
    capacity = max(int(t * k / e * m.capacity_factor), min(t * k, 4))

    # Position of each (token, choice) within its expert's bucket, by STABLE
    # SORT rank (§Perf iteration A2).  The one-hot cumsum formulation costs
    # O((t·k)²·e) in XLA's cumulative-op cost model and serializes across
    # the data-sharded token axis; sort-based ranking is O(n log n) and
    # yields the identical first-come-first-served assignment.
    flat_e = expert_idx.reshape(-1)  # (t·k,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))  # (e,)
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - seg_start[sorted_e]
    pos = (
        jnp.zeros((t * k,), jnp.int32).at[sort_idx].set(pos_sorted).reshape(t, k)
    )
    keep = pos < capacity

    if m.dispatch == "einsum":
        # GShard-style one-hot einsum dispatch.  Costs O(T·E·C·d) matmul
        # flops — E× the useful expert compute for top-1 — kept ONLY as the
        # §Perf iteration-0 reference (see EXPERIMENTS.md).
        onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (T, k, E)
        pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32) * keep[..., None]
        dispatch = jnp.einsum("tke,tkc->tec", onehot, pos_oh)
        combine = jnp.einsum("tke,tkc->tec", onehot * gate_vals[..., None], pos_oh)
        dispatch = shard(dispatch.astype(x.dtype), ("batch", "experts", None))
        ein = jnp.einsum("tec,td->ecd", dispatch, xt)
    else:
        # Gather/scatter dispatch (default): index arithmetic instead of
        # one-hot matmuls — zero matmul overhead beyond the expert FFNs.
        flat_slot = expert_idx * capacity + pos  # (T, k) in [0, E·C)
        flat_slot = jnp.where(keep, flat_slot, e * capacity)  # overflow slot
        token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k))
        src = jnp.full((e * capacity + 1,), t, jnp.int32)  # t = "no token"
        src = src.at[flat_slot.reshape(-1)].set(token_ids.reshape(-1))[:-1]
        x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
        ein = x_pad[src].reshape(e, capacity, d)

    ein = shard(ein, ("experts", None, None))
    g = jnp.einsum("ecd,edf->ecf", ein, p["e_gate"])
    u = jnp.einsum("ecd,edf->ecf", ein, p["e_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    eout = jnp.einsum("ecf,efd->ecd", h, p["e_down"])
    eout = shard(eout, ("experts", None, None))

    if m.dispatch == "einsum":
        out = jnp.einsum(
            "tec,ecd->td", combine, eout.astype(jnp.float32)
        ).astype(x.dtype)
    else:
        # combine = gather each (token, choice)'s slot output, gate-weight.
        flat_out = eout.reshape(e * capacity, d)
        slot = jnp.where(keep, expert_idx * capacity + pos, 0)
        picked = flat_out[slot.reshape(-1)].reshape(t, k, d)
        picked = jnp.where(keep[..., None], picked, 0)
        out = jnp.einsum(
            "tkd,tk->td", picked.astype(jnp.float32), gate_vals
        ).astype(x.dtype)
    if "shared" in p:
        out = out + swiglu(xt, **p["shared"])
    out = out.reshape(b, s, d)

    # aux: load-balance (f_i · P_i · E) + z-loss.  Dispatch fraction via
    # scatter-add (no (T,k,E) one-hot materialization).
    density = (
        jnp.zeros((e,), jnp.float32).at[expert_idx[:, 0]].add(1.0) / t
    )
    router_prob = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(density * router_prob)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return out, {"lb_loss": lb_loss, "z_loss": z_loss}
