"""Mamba2 (SSD) mixer — chunkwise-parallel scan, TPU-matmul-heavy form.

The state-space dual form processes the sequence in chunks: within-chunk
interactions are dense matmuls (MXU-friendly), cross-chunk interactions
carry an (nh, hd, N) state through a lax.scan over chunks.  The chunk
boundary state is exactly the paper's weak-memory halo in chunk index —
order-1 in chunks — which is how sequence parallelism shards it
(DESIGN.md §4).

Decode is the O(1) recurrence: h ← dA·h + dt·x⊗B,  y = C·h + D·x.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, dense_init, rms_norm
from ..parallel.sharding import shard

Params = Dict[str, Any]


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    return d_in, nh, s.state_dim, s.conv_width


def mamba2_init(key, cfg, dtype=DTYPE) -> Params:
    d_in, nh, n, cw = _dims(cfg)
    conv_ch = d_in + 2 * n  # x, B, C go through the causal conv
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * d_in + 2 * n + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cw, conv_ch)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], d_in, cfg.d_model, dtype),
    }


def _split_proj(cfg, proj):
    d_in, nh, n, _ = _dims(cfg)
    z, xc, bc, cc, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    return z, xc, bc, cc, dt


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array, state: Optional[jax.Array]):
    """Depthwise causal conv; ``state`` is the (cw−1) trailing inputs of the
    previous segment (zeros at sequence start).  Returns (out, new_state)."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((seq.shape[0], cw - 1, seq.shape[-1]), seq.dtype)
    padded = jnp.concatenate([state, seq], axis=1)
    out = sum(
        padded[:, i : i + seq.shape[1]] * w[i][None, None, :] for i in range(cw)
    )
    out = jax.nn.silu((out + b[None, None, :]).astype(jnp.float32))
    return out, padded[:, -(cw - 1) :]


def mamba2_apply(
    p: Params,
    x: jax.Array,  # (B, S, d)
    cfg,
    *,
    state: Optional[Params] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    d_in, nh, n, cw = _dims(cfg)
    hd = cfg.ssm.head_dim
    chunk = cfg.ssm.chunk
    b, s, _ = x.shape

    proj = jnp.einsum("bsd,dh->bsh", x, p["in_proj"])
    proj = shard(proj, ("batch", None, "ff"))
    z, xc, bc, cc, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, p["conv_w"], p["conv_b"], None if state is None else state["conv"]
    )
    xc = conv_out[..., :d_in].astype(x.dtype)
    bc = conv_out[..., d_in : d_in + n].astype(jnp.float32)  # (B,S,N)
    cc = conv_out[..., d_in + n :].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    a = -jnp.exp(p["A_log"])  # (nh,)
    log_da = dt * a[None, None, :]  # (B,S,nh) log decay

    xh = xc.reshape(b, s, nh, hd).astype(jnp.float32)
    h0 = (
        state["ssd"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((b, nh, hd, n), jnp.float32)
    )

    if s == 1:
        # O(1) decode recurrence
        da = jnp.exp(log_da[:, 0])  # (B,nh)
        h = h0 * da[..., None, None] + (dt[:, 0])[..., None, None] * jnp.einsum(
            "bhp,bn->bhpn", xh[:, 0], bc[:, 0]
        )
        y = jnp.einsum("bhpn,bn->bhp", h, cc[:, 0]) + p["D"][None, :, None] * xh[:, 0]
        y = y[:, None]  # (B,1,nh,hd)
        new_state = {"conv": conv_state, "ssd": h}
    else:
        # pad to a chunk multiple; padded steps are exact identities in the
        # recurrence (dt := 0 ⇒ no decay, no input) so the final state is
        # unaffected and padded outputs are sliced away.
        s_orig = s
        pad = (-s) % chunk
        if pad:
            step_mask = (jnp.arange(s + pad) < s).astype(jnp.float32)
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0))) * step_mask[None, :, None]
            log_da = jnp.pad(log_da, ((0, 0), (0, pad), (0, 0)))
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            bc = jnp.pad(bc, ((0, 0), (0, pad), (0, 0)))
            cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
            log_da = log_da * step_mask[None, :, None]
            s = s + pad
        nc = s // chunk
        ld = log_da.reshape(b, nc, chunk, nh)
        cum = jnp.cumsum(ld, axis=2)  # inclusive within-chunk cumulation
        xcks = xh.reshape(b, nc, chunk, nh, hd)
        bck = bc.reshape(b, nc, chunk, n)
        cck = cc.reshape(b, nc, chunk, n)
        dtk = dt.reshape(b, nc, chunk, nh)

        # within-chunk (diagonal) term
        li = cum[:, :, :, None, :]  # (b,nc,l,1,h)
        sj = cum[:, :, None, :, :]  # (b,nc,1,s,h)
        decay = jnp.exp(li - sj)  # (b,nc,l,s,h)
        causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
        scores = (
            jnp.einsum("bcln,bcsn->bcls", cck, bck)[..., None]
            * decay
            * causal[None, None, :, :, None]
            * dtk[:, :, None, :, :]
        )  # (b,nc,l,s,h)
        y_diag = jnp.einsum("bclsh,bcshp->bclhp", scores, xcks)

        # chunk summary states and cross-chunk scan
        tail = jnp.exp(cum[:, :, -1:, :] - cum)  # decay from step s to chunk end
        s_local = jnp.einsum(
            "bcsh,bcsn,bcshp->bchpn", tail * dtk, bck, xcks
        )  # (b,nc,nh,hd,n)
        chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,nh)

        def scan_body(h_prev, inp):
            s_loc, cdec = inp
            h_new = h_prev * cdec[..., None, None] + s_loc
            return h_new, h_prev

        (h_final, h_prevs) = jax.lax.scan(
            scan_body,
            h0,
            (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        )
        h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (b,nc,nh,hd,n) state entering chunk

        y_off = jnp.einsum(
            "bcln,bchpn,bclh->bclhp", cck, h_prevs, jnp.exp(cum)
        )
        y = (y_diag + y_off).reshape(b, s, nh, hd) + p["D"][None, None, :, None] * xh
        y = y[:, :s_orig]
        new_state = {"conv": conv_state, "ssd": h_final}

    y = y.reshape(b, -1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsh,hd->bsd", y, p["out_proj"])
    return out, (new_state if (return_state or state is not None) else None)


def mamba2_state_spec(cfg, batch: int, dtype=DTYPE) -> Dict[str, Any]:
    d_in, nh, n, cw = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "conv": jax.ShapeDtypeStruct((batch, cw - 1, conv_ch), dtype),
        "ssd": jax.ShapeDtypeStruct((batch, nh, cfg.ssm.head_dim, n), jnp.float32),
    }
