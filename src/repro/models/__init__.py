"""Assigned-architecture model zoo (pure JAX, scan-over-layers)."""
from . import attention, encdec, layers, moe, model_zoo, ssm, transformer, vlm_stub, xlstm, xlstm_lm, zamba
from .model_zoo import (
    init_params,
    forward,
    prefill,
    decode_step,
    cache_spec,
    input_specs,
)
