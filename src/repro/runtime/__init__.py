from .fault import FaultTolerantLoop, StragglerMonitor, ElasticPlan, plan_remesh
