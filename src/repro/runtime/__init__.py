from .chaos import FaultInjector, InjectedFault
from .fault import FaultTolerantLoop, StragglerMonitor, ElasticPlan, plan_remesh
