"""Seedable fault injection: prove the stack degrades instead of dying.

The paper's premise — weak-memory statistics survive fragmentation and
replication "across many machines" — only holds in production if the stack
survives the failures those machines actually have: a kernel build that
starts raising, a checkpoint torn mid-write, a straggler device stalling a
serving tick.  PR 6 hardened the crash paths *reactively*; this module is
the proactive half: a deterministic, seedable :class:`FaultInjector` whose
named **injection sites** are threaded through the layers that can fail —

  ``backend.<primitive>``   fired by `repro.core.backend
                            .CircuitBreakerBackend` right before the
                            primary (Pallas) kernel dispatch of that
                            primitive — a ``fail`` rule here looks exactly
                            like a kernel build/dispatch raising;
  ``checkpoint.write``      fired at the top of `repro.checkpoint.manager
                            .save_pytree` — a ``fail`` rule models a
                            transient IO error (exercises the manager's
                            bounded retry-with-backoff);
  ``checkpoint.payload``    checked (``should_corrupt``) after the arrays
                            payload is written — a ``corrupt`` rule tears
                            the bytes on disk, exercising checksum
                            verification and generation walk-back;
  ``gateway.tick``          fired inside `repro.serving.gateway
                            .StatsGateway.tick`'s timed window — a
                            ``stall`` rule models a straggler device and
                            exercises the tick deadline / degraded mode;
  ``ingest.payload``        checked (``should_corrupt``) once per ADMITTED
                            ingest submission in `repro.serving.gateway
                            .StatsGateway.submit_ingest` — a ``corrupt``
                            rule poisons the payload with a NaN before it
                            is enqueued, exercising the ingest sentinel,
                            per-tenant poisoning policies, and tenant
                            rebuild.  Call order == submission order, so
                            a ``calls={k}`` schedule targets a specific
                            (tick, tenant) deterministically.

Schedules are deterministic: rules match explicit 0-based call indices of
their site (``calls={2, 3}`` — "fail the 3rd and 4th dispatch") and/or a
seeded per-site Bernoulli rate (``rate=0.01``), so a chaos run replays
bit-for-bit.  Install an injector process-wide with :func:`install` (or the
:func:`scoped` context manager, which the tests use); every call site goes
through the module-level :func:`fire` / :func:`should_corrupt`, which are
no-ops when nothing is installed — zero overhead on the un-injected path.

    inj = FaultInjector(seed=0)
    inj.fail("backend.fused_plan_update", calls=range(3, 6))
    inj.corrupt("checkpoint.payload", calls={1})
    inj.stall("gateway.tick", calls={4}, seconds=0.2)
    with scoped(inj):
        ...   # drive the gateway; inj.log records every firing
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import zlib
from typing import Dict, Iterable, List, Optional

import numpy as np

__all__ = [
    "InjectedFault",
    "FaultInjector",
    "install",
    "installed",
    "clear",
    "scoped",
    "fire",
    "should_corrupt",
]


class InjectedFault(RuntimeError):
    """The error a ``fail`` rule raises at its site (chaos, not a real bug)."""


@dataclasses.dataclass
class _Rule:
    site: str
    action: str                      # "fail" | "stall" | "corrupt"
    calls: Optional[frozenset]       # explicit 0-based call indices, or None
    rate: float = 0.0                # seeded Bernoulli, evaluated per call
    seconds: float = 0.0             # stall duration
    exc: type = InjectedFault        # what a fail rule raises

    def matches(self, n: int, draw: float) -> bool:
        if self.calls is not None and n in self.calls:
            return True
        return self.rate > 0.0 and draw < self.rate


def _as_calls(calls) -> Optional[frozenset]:
    if calls is None:
        return None
    if isinstance(calls, (int, np.integer)):
        return frozenset({int(calls)})
    return frozenset(int(c) for c in calls)


class FaultInjector:
    """A deterministic schedule of faults over named injection sites.

    Every site keeps its own 0-based call counter and its own seeded RNG
    substream (derived from ``seed`` and the site name), so adding a rule
    on one site never perturbs the draws — or the schedule — of another.
    ``log`` records every firing as ``(site, call_index, action)``; the
    per-site counters are exposed via :meth:`count`.
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._rules: List[_Rule] = []
        self._counts: Dict[str, int] = {}
        self._rngs: Dict[str, np.random.RandomState] = {}
        self.log: List[tuple] = []

    # -- schedule construction --------------------------------------------
    def fail(
        self,
        site: str,
        calls: Optional[Iterable[int]] = None,
        rate: float = 0.0,
        exc: type = InjectedFault,
    ) -> "FaultInjector":
        """Raise ``exc`` at the matching calls of ``site``."""
        self._rules.append(_Rule(site, "fail", _as_calls(calls), rate, exc=exc))
        return self

    def stall(
        self,
        site: str,
        calls: Optional[Iterable[int]] = None,
        rate: float = 0.0,
        seconds: float = 0.2,
    ) -> "FaultInjector":
        """Sleep ``seconds`` at the matching calls of ``site``."""
        self._rules.append(
            _Rule(site, "stall", _as_calls(calls), rate, seconds=float(seconds))
        )
        return self

    def corrupt(
        self,
        site: str,
        calls: Optional[Iterable[int]] = None,
        rate: float = 0.0,
    ) -> "FaultInjector":
        """Report ``True`` from :meth:`should_corrupt` at the matching calls
        (the call site owns *how* to tear its payload)."""
        self._rules.append(_Rule(site, "corrupt", _as_calls(calls), rate))
        return self

    # -- firing ------------------------------------------------------------
    def _rng(self, site: str) -> np.random.RandomState:
        rng = self._rngs.get(site)
        if rng is None:
            sub = (zlib.crc32(site.encode()) ^ self.seed) & 0xFFFFFFFF
            rng = self._rngs[site] = np.random.RandomState(sub)
        return rng

    def _step(self, site: str) -> tuple:
        n = self._counts.get(site, 0)
        self._counts[site] = n + 1
        # one draw per call whether or not any rule is rated, so adding a
        # calls= rule never shifts a rate= rule's later draws on this site
        draw = float(self._rng(site).random_sample())
        return n, draw

    def fire(self, site: str) -> None:
        """One call at ``site``: apply any matching stall, then any
        matching fail (stalls-then-raise composes both)."""
        n, draw = self._step(site)
        failed: Optional[_Rule] = None
        for rule in self._rules:
            if rule.site != site or not rule.matches(n, draw):
                continue
            if rule.action == "stall":
                self.log.append((site, n, "stall"))
                time.sleep(rule.seconds)
            elif rule.action == "fail":
                failed = failed or rule
        if failed is not None:
            self.log.append((site, n, "fail"))
            raise failed.exc(
                f"injected fault at {site!r} (call {n}, seed {self.seed})"
            )

    def should_corrupt(self, site: str) -> bool:
        """One call at ``site``: does a ``corrupt`` rule match it?"""
        n, draw = self._step(site)
        for rule in self._rules:
            if rule.site == site and rule.action == "corrupt" and rule.matches(n, draw):
                self.log.append((site, n, "corrupt"))
                return True
        return False

    def count(self, site: str) -> int:
        """How many times ``site`` has fired (0-based next index)."""
        return self._counts.get(site, 0)


# -- process-wide installation (what the threaded call sites read) ----------
_ACTIVE: Optional[FaultInjector] = None


def install(injector: FaultInjector) -> None:
    """Make ``injector`` the process-wide active schedule."""
    global _ACTIVE
    _ACTIVE = injector


def installed() -> Optional[FaultInjector]:
    return _ACTIVE


def clear() -> None:
    global _ACTIVE
    _ACTIVE = None


@contextlib.contextmanager
def scoped(injector: FaultInjector):
    """Install ``injector`` for the duration of a with-block (test scope)."""
    global _ACTIVE
    prev = _ACTIVE
    install(injector)
    try:
        yield injector
    finally:
        _ACTIVE = prev


def fire(site: str) -> None:
    """Module-level hook the instrumented layers call: no-op when no
    injector is installed, else one counted call at ``site``."""
    if _ACTIVE is not None:
        _ACTIVE.fire(site)


def should_corrupt(site: str) -> bool:
    if _ACTIVE is not None:
        return _ACTIVE.should_corrupt(site)
    return False
