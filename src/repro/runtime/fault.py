"""Fault tolerance runtime: preemption, stragglers, elastic re-meshing.

SPMD has no per-task retry (unlike the paper's Spark host system), so the
fault model is: detect → checkpoint (or fall back to the last async
checkpoint) → re-plan the mesh without the failed hosts → restore → resume.
The pieces:

  * FaultTolerantLoop — wraps the step loop: periodic async checkpoints,
    SIGTERM/preemption hook that flushes a final checkpoint, automatic
    resume from the latest checkpoint on (re)start.
  * StragglerMonitor — EWMA step-time tracker; flags steps slower than
    ``threshold ×`` the running median.  On TPU pods a straggling *host*
    stalls the whole program, so mitigation = surface it (callback) and, at
    the orchestration layer, restart excluding the slow host (plan_remesh).
  * plan_remesh — given a surviving device count, pick the largest
    (data, model) grid compatible with the model's divisibility constraints
    — the elastic-scaling decision function (unit-tested; drives
    restore-time shardings via checkpoint.restore_pytree).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class ElasticPlan:
    data: int
    model: int
    dropped_devices: int

    @property
    def world(self) -> int:
        return self.data * self.model


def plan_remesh(
    surviving_devices: int,
    *,
    model_divisors: Tuple[int, ...] = (16, 8, 4, 2, 1),
    prefer_model: int = 16,
) -> ElasticPlan:
    """Largest usable (data × model) grid ≤ surviving_devices.

    Keeps the model axis at the largest divisor ≤ prefer_model that still
    divides a usable world size; data gets the rest.  Drops remainder
    devices (they idle until the next full re-plan).
    """
    for m in model_divisors:
        if m > prefer_model:
            continue
        data = surviving_devices // m
        if data >= 1:
            return ElasticPlan(data=data, model=m,
                               dropped_devices=surviving_devices - data * m)
    raise ValueError("no usable mesh for zero devices")


class StragglerMonitor:
    """EWMA + median step-time tracking with a slow-step callback.

    A step is flagged once the history holds at least ``min(8, window)``
    samples AND the step exceeds ``threshold ×`` the windowed median —
    STRICTLY exceeds, so a step landing exactly on the threshold is not a
    straggler.  (The warm-up used to be a flat 8, so a monitor configured
    with ``window < 8`` could never flag anything.)
    """

    WARMUP = 8

    def __init__(self, threshold: float = 2.0, window: int = 64,
                 on_straggle: Optional[Callable[[int, float, float], None]] = None):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.flagged: List[int] = []
        self.on_straggle = on_straggle

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window :]
        med = float(np.median(hist))
        warmup = min(self.WARMUP, self.window)
        slow = len(hist) >= warmup and seconds > self.threshold * med
        if slow:
            self.flagged.append(step)
            if self.on_straggle:
                self.on_straggle(step, seconds, med)
        return slow


class FaultTolerantLoop:
    """Checkpointed, preemption-aware step loop driver.

    Usage:
        loop = FaultTolerantLoop(ckpt_dir, every=100)
        state, start = loop.restore_or(init_state)       # resume if possible
        for step in range(start, total):
            state, metrics = step_fn(state, batch)
            loop.after_step(step, state)                  # async ckpt + timing
    """

    def __init__(
        self,
        directory: str,
        *,
        every: int = 100,
        keep: int = 3,
        straggler_threshold: float = 2.0,
        install_signal_handler: bool = False,
    ):
        self.manager = CheckpointManager(directory, keep=keep)
        self.every = every
        self.monitor = StragglerMonitor(threshold=straggler_threshold)
        self._last_state: Any = None
        self._last_step: int = -1
        self._last_saved_step: Optional[int] = None
        self.last_restore_skipped: List[int] = []
        # Step timing starts at the first after_step: anchoring it here
        # would bill construction + restore wall time (checkpoint reads,
        # device_put, first-step compile waits...) to step 0 and poison
        # the straggler median for the whole window.
        self._t_prev: Optional[float] = None
        self.preempted = False
        if install_signal_handler:
            signal.signal(signal.SIGTERM, self._on_preempt)

    # -- resume -----------------------------------------------------------
    def restore_or(self, init_state: Any, shardings: Any = None) -> Tuple[Any, int]:
        """Resume from the newest INTACT generation, or start fresh.

        Restores walk back past torn/corrupt generations
        (`repro.checkpoint.manager.restore_latest_intact`); the ones
        skipped are recorded in ``last_restore_skipped`` so the caller can
        surface the freshness loss.  When every retained generation is
        corrupt, resume-from-zero beats dying — the cold start is taken and
        the skipped list says why.
        """
        from ..checkpoint.manager import CheckpointCorrupt, restore_latest_intact

        self.last_restore_skipped: List[int] = []
        try:
            state, step, skipped = restore_latest_intact(
                init_state, self.manager.directory, shardings
            )
        except FileNotFoundError:
            return init_state, 0
        except CheckpointCorrupt as e:
            from ..checkpoint.manager import list_steps

            self.last_restore_skipped = list(
                reversed(list_steps(self.manager.directory))
            )
            import warnings

            warnings.warn(
                f"every retained checkpoint generation is corrupt — "
                f"starting fresh ({e})",
                RuntimeWarning,
            )
            return init_state, 0
        self.last_restore_skipped = skipped
        return state, step + 1

    # -- per-step ---------------------------------------------------------
    def after_step(self, step: int, state: Any) -> None:
        now = time.monotonic()
        if self._t_prev is not None:
            self.monitor.record(step, now - self._t_prev)
        self._t_prev = now
        self._last_state, self._last_step = state, step
        if self.every and (step + 1) % self.every == 0:
            self.manager.save(state, step)
            self._last_saved_step = step
        if self.preempted:
            self.checkpoint_now()
            raise SystemExit(f"preempted at step {step}; checkpoint flushed")

    # -- preemption -------------------------------------------------------
    def _on_preempt(self, signum, frame):  # pragma: no cover - signal path
        self.preempted = True

    def checkpoint_now(self) -> None:
        # skip the re-save when the periodic path already wrote this step —
        # the duplicate serialized the same state twice on every preemption
        # that landed on a checkpoint boundary
        if self._last_state is not None and self._last_step != self._last_saved_step:
            self.manager.save(self._last_state, self._last_step)
            self._last_saved_step = self._last_step
        self.manager.flush()

    def close(self) -> None:
        self.manager.close()
